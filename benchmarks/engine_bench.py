"""Round-engine benchmarks.

Two benches live here:

* ``bench``       — client-phase wall-clock: sequential vs batched vs fused,
                    plus the PR-1 full-head batched engine as the historical
                    reference (writes BENCH_engine[.quick].json).
* ``bench_round`` — WHOLE-round wall-clock (client phase + server phase:
                    aggregation + server distillation + broadcast): the PR-2
                    "fused client phase + host server phase over densified
                    (N, B, V) stacks" against the PR-3 fused-e2e single
                    compiled call over the sparse (values, indices, mask)
                    wire, and the ``run_rounds`` multi-round lax.scan driver
                    (writes BENCH_round[.quick].json, incl. the aggregation
                    working-set bytes and a trace-inspection proof that the
                    sparse aggregation path materialises no (N, B, V) dense
                    stack).

The paper's Algorithm 1 selects 10 of 50 clients per round.  Engines:

  sequential   — one jitted call per client per step (O(C*steps) dispatches)
  batched      — vmapped per-phase steps (O(steps) dispatches), last-only head
  batched_pr1  — the PR-1 batched engine: same structure but the LM head
                 materialises the full (B, T, V) logits each phase
  fused        — ONE donated jitted call for the whole client phase
                 (distill -> fine-tune -> public inference -> adaptive top-k
                 with k as data), last-only head

At vocab >= 8k the (B, T, V) head is the dominant FLOP term, so the
last-only head (a ~T× cut on that term) is where the fused/batched engines
gain; the fused engine additionally removes per-phase dispatch/host
round-trips.  The headline ratio is fused vs batched_pr1 — new engine
against what shipped in PR 1 on identical state.

Caveat for CPU readings: XLA's CPU backend lowers cohort-batched matmuls as
loops of per-client GEMMs, so client-axis batching itself is roughly neutral
here (see PR 1 README notes); the speedups below come from the head cut and
dispatch fusion, which ARE realised on this machine.  The ratio printed is
an honest measurement of THIS machine, not an accelerator projection.

Run:  PYTHONPATH=src python -m benchmarks.run --only engine
  or: PYTHONPATH=src python benchmarks/engine_bench.py [--quick] [--shard N]
      (writes BENCH_engine.json next to the repo root; --shard N forces N
      host CPU devices BEFORE jax initialises so bench_round can measure the
      sharded fused_e2e round — fused_e2e_shard — in the same process)
"""

from __future__ import annotations

import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# --shard N (or --shard=N) must act BEFORE jax initialises: it forces N host
# CPU devices so bench_round can measure the sharded fused_e2e round against
# the unsharded one IN THE SAME environment (every variant then sees N
# devices).
if __name__ == "__main__":
    for _i, _arg in enumerate(sys.argv):
        if _arg == "--shard" or _arg.startswith("--shard="):
            if "=" in _arg:
                _n = int(_arg.split("=", 1)[1])
            elif _i + 1 < len(sys.argv):
                _n = int(sys.argv[_i + 1])
            else:
                sys.exit("--shard requires a device count (e.g. --shard 2)")
            _cores = os.cpu_count() or 1
            if _n < 1:
                sys.exit(f"--shard {_n}: device count must be >= 1")
            if _n > _cores:
                sys.exit(
                    f"--shard {_n} exceeds this host's {_cores} cores: forced "
                    "host devices share the physical core pool, so "
                    "oversubscribing it would only measure scheduler thrash "
                    f"(pick --shard <= {_cores})"
                )
            os.environ["XLA_FLAGS"] = (
                os.environ.get("XLA_FLAGS", "")
                + f" --xla_force_host_platform_device_count={_n}"
            ).strip()
            break

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

_REPO_ROOT = os.path.join(os.path.dirname(__file__), "..")


def _build(num_clients: int, *, d_model: int, vocab: int, seq_len: int):
    from repro.configs.base import LoRAConfig
    from repro.configs.gpt2_paper import REDUCED_CLIENT
    from repro.data import make_banking77_like
    from repro.fed.client import Client
    from repro.fed.engine import BatchedEngine, BroadcastState, FusedEngine, SequentialEngine

    lora = LoRAConfig(rank=8, alpha=32.0, dropout=0.0, targets=("q", "v", "head"))
    cfg = REDUCED_CLIENT.with_overrides(
        num_layers=2, d_model=d_model, num_heads=4, num_kv_heads=4,
        d_ff=2 * d_model, vocab_size=vocab, max_seq_len=max(seq_len, 32), lora=lora,
    )
    ds = make_banking77_like(vocab_size=vocab, seq_len=seq_len, total=60 * num_clients + 200, seed=0)

    # One shared pretrained-like backbone W' under per-client LoRA deltas —
    # the paper's setting, and what run_federated produces after pretraining.
    from repro.models import init as model_init

    backbone = model_init(jax.random.PRNGKey(123), cfg)

    def cohort():
        return [
            Client(i, cfg, ds.subset(np.arange(i * 60, (i + 1) * 60)),
                   num_classes=ds.num_classes, seed=i, local_steps=4, distill_steps=2,
                   initial_params=backbone)
            for i in range(num_clients)
        ]

    pub = jnp.asarray(ds.tokens[-64:])
    g_logits = jax.random.normal(jax.random.PRNGKey(0), (pub.shape[0], vocab))
    g_h = jax.random.normal(jax.random.PRNGKey(1), (pub.shape[0], lora.rank))
    bcast = BroadcastState(tokens=pub, logits=g_logits, h=g_h, bits=0)

    mk = dict(num_classes=ds.num_classes, local_steps=4, distill_steps=2)
    engines = {
        "sequential": SequentialEngine(cohort(), cfg),
        "batched": BatchedEngine(cohort(), cfg, **mk),
        "batched_pr1": BatchedEngine(cohort(), cfg, last_only=False, **mk),
        "fused": FusedEngine(cohort(), cfg, **mk),
    }
    return cfg, engines, pub, bcast


def _time_round(engine, sel, pub, bcast, states, reps: int) -> float:
    # warm-up: compile every step shape this engine will touch
    engine.run_round(sel, pub, bcast, states, adaptive_k=True, send_h=True)
    t0 = time.time()
    for _ in range(reps):
        phase = engine.run_round(sel, pub, bcast, states, adaptive_k=True, send_h=True)
        if phase.dense is not None:
            jax.block_until_ready(phase.dense)
    return (time.time() - t0) / reps * 1e6  # us per client phase


def bench(quick: bool = True, out_json: str | None = None):
    """Rows: (name, us_per_round_client_phase, derived)."""
    from repro.core import ChannelConfig, ChannelSimulator

    num_clients = 10  # the paper's clients_per_round
    # vocab >= 8k: the regime the last-only head targets (paper-scale heads
    # are 50k-256k; 8k keeps the full-head PR-1 reference benchable on CPU)
    d_model, vocab, seq_len = (64, 8192, 16) if quick else (128, 8192, 16)
    reps = 2 if quick else 3

    cfg, engines, pub, bcast = _build(
        num_clients, d_model=d_model, vocab=vocab, seq_len=seq_len
    )
    sim = ChannelSimulator(num_clients, ChannelConfig(bandwidth_hz=5e5, mean_snr_db=5.0), seed=0)
    sel = list(range(num_clients))
    states = sim.states_batched(0, sel)

    us = {
        name: _time_round(eng, sel, pub, bcast, states, reps)
        for name, eng in engines.items()
    }
    speedups = {
        "fused_vs_batched_pr1": us["batched_pr1"] / us["fused"],
        "fused_vs_batched": us["batched"] / us["fused"],
        "batched_vs_batched_pr1": us["batched_pr1"] / us["batched"],
        "fused_vs_sequential": us["sequential"] / us["fused"],
    }
    shape = f"C={num_clients};L2;d{d_model};V{vocab};T{seq_len};steps=4+2"

    if out_json:
        record = {
            "bench": "engine_round",
            "shape": shape,
            "quick": quick,
            "reps": reps,
            "backend": jax.default_backend(),
            "cpu_count": os.cpu_count(),
            "us_per_client_phase": {k: round(v) for k, v in us.items()},
            "speedups": {k: round(v, 2) for k, v in speedups.items()},
            "notes": (
                "batched_pr1 = PR-1 full-(B,T,V)-head batched engine; "
                "fused/batched use the last-only LM head.  CPU container "
                "measurement (XLA CPU lowers cohort-batched GEMMs as loops)."
            ),
        }
        with open(out_json, "w") as f:
            json.dump(record, f, indent=1)

    return [
        ("engine_sequential_round", us["sequential"], shape),
        ("engine_batched_round", us["batched"], shape),
        ("engine_batched_pr1_round", us["batched_pr1"], f"{shape};full-head"),
        ("engine_fused_round", us["fused"],
         f"{shape};vs_pr1={speedups['fused_vs_batched_pr1']:.2f}x"),
    ]


def _assert_agg_dense_stack_free(n: int, rows: int, vocab: int, k_cap: int) -> int:
    """Trace-inspect the sparse aggregation path: build its jaxpr at the
    round's shapes and verify NO intermediate (sub-jaxprs included) reaches
    the (N, rows, V) dense stack's element count (the dense oracle's
    working set).  Returns the largest intermediate element count seen.
    Uses the same shared inspection as the CI test
    (tests/test_engine.py::test_e2e_aggregation_path_never_densifies_stack)."""
    from repro.core.aggregation import aggregate_wire, max_intermediate_elems
    from repro.core.topk import SparseWire

    def agg(values, indices, mask, n_tx):
        wire = SparseWire(values=values, indices=indices, mask=mask, vocab=vocab)
        return aggregate_wire(wire, "adaptive", num_transmitters=n_tx)

    jaxpr = jax.make_jaxpr(agg)(
        jnp.zeros((n, rows, k_cap)), jnp.zeros((n, rows, k_cap), jnp.int32),
        jnp.zeros((n, rows, k_cap), bool), jnp.int32(n),
    )
    worst = max_intermediate_elems(jaxpr)
    dense_stack = n * rows * vocab
    assert worst < dense_stack, (
        f"sparse aggregation materialised {worst} elements >= the dense "
        f"(N, B, V) stack's {dense_stack}"
    )
    return worst


def bench_round(quick: bool = True, out_json: str | None = None):
    """Whole-round wall-clock (client + server phases), three executions:

    fused_host — PR-2 fused client phase (ONE call) + HOST server phase:
                 densified (N, P, V) stack -> aggregate_dense -> per-step
                 server distill dispatches -> broadcast inference.
    fused_e2e  — PR-3: the whole round as ONE donated compiled call over the
                 sparse (values, indices, mask) wire.
    e2e_scanR  — R whole rounds inside one lax.scan dispatch
                 (``FusedE2EEngine.run_rounds``), reported per round.
    """
    from repro.core import ChannelConfig, ChannelSimulator
    from repro.fed.engine import BroadcastState, FusedE2EEngine, k_cap_bucket
    from repro.fed.server import Server

    num_clients = 10  # the paper's clients_per_round
    # P = 256 is the FedConfig default public_batch — at that size the round
    # is aggregation/sparsifier-bound (the regime the sparse wire targets),
    # not model-GEMM-bound like a P=64 toy batch.  Both modes use the d64
    # reduced model: the round bench measures ROUND ARCHITECTURE (dispatch,
    # wire vs dense stacks), not model size; full mode adds reps.
    d_model, vocab, seq_len, pub_batch = 64, 8192, 16, 256
    # the container's noise events last minutes: only several interleaved
    # reps with a min give each variant a shot at a clean window
    reps = 4 if quick else 6
    scan_rounds = 3
    server_distill_steps = 12  # FedConfig default: the server LLM's phase

    from repro.configs.base import LoRAConfig
    from repro.configs.gpt2_paper import REDUCED_CLIENT
    from repro.data import make_banking77_like
    from repro.fed.client import Client
    from repro.fed.engine import FusedEngine
    from repro.models import init as model_init

    lora = LoRAConfig(rank=8, alpha=32.0, dropout=0.0, targets=("q", "v", "head"))
    cfg = REDUCED_CLIENT.with_overrides(
        num_layers=2, d_model=d_model, num_heads=4, num_kv_heads=4,
        d_ff=2 * d_model, vocab_size=vocab, max_seq_len=max(seq_len, 32), lora=lora,
    )
    ds = make_banking77_like(
        vocab_size=vocab, seq_len=seq_len, total=60 * num_clients + pub_batch + 100,
        seed=0,
    )
    backbone = model_init(jax.random.PRNGKey(123), cfg)

    def cohort():
        return [
            Client(i, cfg, ds.subset(np.arange(i * 60, (i + 1) * 60)),
                   num_classes=ds.num_classes, seed=i, local_steps=4,
                   distill_steps=2, initial_params=backbone)
            for i in range(num_clients)
        ]

    pub = jnp.asarray(ds.tokens[-pub_batch:])
    n_samples = int(pub.shape[0])
    sim = ChannelSimulator(
        num_clients, ChannelConfig(bandwidth_hz=5e5, mean_snr_db=5.0), seed=0
    )
    sel = list(range(num_clients))
    states = sim.states_batched(0, sel)
    mk = dict(num_classes=ds.num_classes, local_steps=4, distill_steps=2)

    # -- PR-2 reference: fused client phase AS SHIPPED (full-vocab
    # supervised head) + host server phase over the dense (N, P, V) stack --
    host_engine = FusedEngine(cohort(), cfg, class_head_only=False, **mk)
    host_server = Server(cfg, aggregation="adaptive",
                         distill_steps=server_distill_steps)
    # -- same host pipeline but with this PR's class-column supervised head
    # (isolates the e2e-specific win from the shared head-FLOP cut) --
    host_cls_engine = FusedEngine(cohort(), cfg, **mk)
    host_cls_server = Server(cfg, aggregation="adaptive",
                             distill_steps=server_distill_steps)

    def make_host_round(engine, server):
        def host_round(bcast):
            phase = engine.run_round(
                sel, pub, bcast, states, adaptive_k=True, send_h=True
            )
            k_g, h_g = server.aggregate_dense(phase.dense, phase.h)
            server.distill(pub, k_g, h_g)
            g_logits, g_h, bits = server.broadcast(pub)
            jax.block_until_ready(g_logits)
            return BroadcastState(tokens=pub, logits=g_logits, h=g_h, bits=bits)
        return host_round

    host_round = make_host_round(host_engine, host_server)
    host_cls_round = make_host_round(host_cls_engine, host_cls_server)

    # -- PR-3: the whole round as one compiled call ------------------------
    e2e_engine = FusedE2EEngine(
        cohort(), cfg,
        server=Server(cfg, aggregation="adaptive",
                      distill_steps=server_distill_steps),
        server_distill_steps=server_distill_steps, aggregation="adaptive", **mk,
    )

    def e2e_round(bcast):
        e2e_engine.run_round(sel, pub, bcast, states, adaptive_k=True, send_h=True)
        jax.block_until_ready(e2e_engine._b_logits)
        return e2e_engine.broadcast_state(pub)

    # -- PR-4: same executable with the client phase sharded over devices
    # (only measurable when the process has >1 device: run with --shard N) --
    shard_round = None
    if jax.device_count() > 1:
        shard_engine = FusedE2EEngine(
            cohort(), cfg,
            server=Server(cfg, aggregation="adaptive",
                          distill_steps=server_distill_steps),
            server_distill_steps=server_distill_steps, aggregation="adaptive",
            shard_clients=True, **mk,
        )

        def shard_round(bcast):
            shard_engine.run_round(
                sel, pub, bcast, states, adaptive_k=True, send_h=True
            )
            jax.block_until_ready(shard_engine._b_logits)
            return shard_engine.broadcast_state(pub)

    # -- R rounds per dispatch (steady-state amortisation) -----------------
    scan_engine = FusedE2EEngine(
        cohort(), cfg,
        server=Server(cfg, aggregation="adaptive",
                      distill_steps=server_distill_steps),
        server_distill_steps=server_distill_steps, aggregation="adaptive", **mk,
    )
    sels = [sel] * scan_rounds
    pubs = [pub] * scan_rounds
    states_r = [sim.states_batched(r, sel) for r in range(scan_rounds)]

    def scan_block():
        scan_engine.run_rounds(sels, pubs, states_r, adaptive_k=True, send_h=True)
        jax.block_until_ready(scan_engine._b_logits)

    # Interleave ALL variants in one loop and keep the MIN per variant: this
    # 2-core container's round-to-round noise (scheduler, neighbours) is
    # 20-50%, and interleaving makes every variant sample the same noise
    # environment instead of whichever regime its back-to-back block hit.
    bc_host = host_round(None)
    bc_host = host_round(bc_host)  # warm-up: cold + warm executables
    bc_cls = host_cls_round(None)
    bc_cls = host_cls_round(bc_cls)
    bc_e2e = e2e_round(None)
    bc_e2e = e2e_round(bc_e2e)
    if shard_round is not None:
        bc_shard = shard_round(None)
        bc_shard = shard_round(bc_shard)
    scan_block()  # compile
    t_host, t_cls, t_e2e, t_shard, t_scan = [], [], [], [], []
    for _ in range(reps):
        t0 = time.time()
        bc_host = host_round(bc_host)
        t_host.append(time.time() - t0)
        t0 = time.time()
        bc_cls = host_cls_round(bc_cls)
        t_cls.append(time.time() - t0)
        t0 = time.time()
        bc_e2e = e2e_round(bc_e2e)
        t_e2e.append(time.time() - t0)
        if shard_round is not None:
            t0 = time.time()
            bc_shard = shard_round(bc_shard)
            t_shard.append(time.time() - t0)
        t0 = time.time()
        scan_block()
        t_scan.append(time.time() - t0)
    us = {
        "fused_host": min(t_host) * 1e6,
        "fused_host_cls": min(t_cls) * 1e6,
        "fused_e2e": min(t_e2e) * 1e6,
        f"e2e_scan{scan_rounds}": min(t_scan) / scan_rounds * 1e6,
    }
    if t_shard:
        us["fused_e2e_shard"] = min(t_shard) * 1e6

    # -- aggregation working set + dense-stack-free proof ------------------
    ks = host_engine._budgets(list(states), n_samples, True, num_clients, True)
    k_cap = k_cap_bucket(ks, vocab)
    n_tx = sum(1 for k in ks if k > 0)
    dense_stack_bytes = n_tx * n_samples * vocab * 4
    wire_bytes = num_clients * n_samples * k_cap * (4 + 4 + 1)
    max_agg_elems = _assert_agg_dense_stack_free(num_clients, n_samples, vocab, k_cap)

    speedups = {
        "e2e_vs_fused_host": us["fused_host"] / us["fused_e2e"],
        "e2e_vs_fused_host_cls": us["fused_host_cls"] / us["fused_e2e"],
        f"scan{scan_rounds}_vs_fused_host": us["fused_host"] / us[f"e2e_scan{scan_rounds}"],
        f"scan{scan_rounds}_vs_e2e": us["fused_e2e"] / us[f"e2e_scan{scan_rounds}"],
    }
    if "fused_e2e_shard" in us:
        speedups["e2e_shard_vs_e2e"] = us["fused_e2e"] / us["fused_e2e_shard"]
    shape = (
        f"C={num_clients};L2;d{d_model};V{vocab};T{seq_len};P{n_samples};"
        f"steps=4+2;srv={server_distill_steps};k_cap={k_cap}"
    )

    if out_json:
        record = {
            "bench": "whole_round",
            "shape": shape,
            "quick": quick,
            "reps": reps,
            "backend": jax.default_backend(),
            "cpu_count": os.cpu_count(),
            "device_count": jax.device_count(),
            "us_per_round": {k: round(v) for k, v in us.items()},
            "speedups": {k: round(v, 2) for k, v in speedups.items()},
            "aggregation": {
                "mean_k": round(float(np.mean(ks)), 1),
                "k_cap": k_cap,
                "num_transmitters": n_tx,
                "dense_stack_bytes": dense_stack_bytes,
                "sparse_wire_bytes": wire_bytes,
                "wire_vs_dense_ratio": round(wire_bytes / dense_stack_bytes, 4),
                "max_agg_intermediate_elems": max_agg_elems,
                "dense_stack_elems": n_tx * n_samples * vocab,
                "agg_dense_stack_free": True,  # asserted above
            },
            "notes": (
                "fused_host = PR-2 fused client phase AS SHIPPED (full-vocab "
                "supervised head) + host server phase over densified (N,P,V) "
                "stacks; fused_host_cls = same host pipeline with the PR-3 "
                "class-column supervised head (isolates the e2e-specific "
                "win); fused_e2e = whole round as ONE compiled call over the "
                f"sparse (values,indices,mask) wire; e2e_scan{scan_rounds} = "
                f"{scan_rounds} rounds per dispatch (run_rounds), per-round "
                "figure; fused_e2e_shard (when device_count > 1, via "
                "--shard N forced host devices) = same executable with the "
                "client phase shard_mapped over devices — on this 2-core CPU "
                "box forced host devices SHARE the core pool, so it bounds "
                "placement overhead rather than projecting accelerator "
                "speedup.  Interleaved min-of-reps on this noisy 2-core CPU "
                "container."
            ),
        }
        with open(out_json, "w") as f:
            json.dump(record, f, indent=1)

    rows = [
        ("round_fused_host", us["fused_host"], f"{shape};pr2-as-shipped"),
        ("round_fused_host_cls", us["fused_host_cls"], f"{shape};cls-head"),
        ("round_fused_e2e", us["fused_e2e"],
         f"{shape};vs_host={speedups['e2e_vs_fused_host']:.2f}x"),
        (f"round_e2e_scan{scan_rounds}", us[f"e2e_scan{scan_rounds}"],
         f"{shape};vs_host={speedups[f'scan{scan_rounds}_vs_fused_host']:.2f}x"),
    ]
    if "fused_e2e_shard" in us:
        rows.append((
            "round_fused_e2e_shard", us["fused_e2e_shard"],
            f"{shape};devs={jax.device_count()}"
            f";vs_e2e={speedups['e2e_shard_vs_e2e']:.2f}x",
        ))
    return rows


def bench_hetero(quick: bool = True, out_json: str | None = None):
    """Heterogeneous-fleet whole-round bench: a mixed 6×mamba2-tiny (SSM) +
    4×gpt2-tiny (dense) cohort — the paper's actual multi-architecture
    scenario — through three executions:

    hetero_seq      — sequential reference clients + host server phase
                      (dense-stack aggregation), one jitted call per client
                      per phase: the only execution the repo had for mixed
                      fleets before PR 5.
    hetero_bucketed — family-bucketed engine: ONE donated compiled
                      client-phase call per family bucket, union sparse
                      wire, ONE compiled server phase.
    hetero_scanR    — R whole heterogeneous rounds inside one lax.scan
                      dispatch (HeteroFusedE2EEngine.run_rounds), per-round
                      figure.
    """
    from repro.configs import get_smoke_config
    from repro.configs.base import LoRAConfig, SSMConfig
    from repro.configs.gpt2_paper import REDUCED_CLIENT
    from repro.core import ChannelConfig, ChannelSimulator
    from repro.data import make_banking77_like
    from repro.fed.client import Client
    from repro.fed.engine import BroadcastState, HeteroFusedE2EEngine, SequentialEngine
    from repro.fed.server import Server

    n_ssm, n_dense = 6, 4
    num_clients = n_ssm + n_dense
    d_model, vocab, seq_len, pub_batch = 64, 8192, 16, 128
    reps = 2 if quick else 3
    scan_rounds = 2
    server_distill_steps = 12

    lora = LoRAConfig(rank=8, alpha=32.0, dropout=0.0, targets=("q", "v", "head"))
    dense_cfg = REDUCED_CLIENT.with_overrides(
        name="bench-dense-tiny", num_layers=2, d_model=d_model, num_heads=4,
        num_kv_heads=4, d_ff=2 * d_model, vocab_size=vocab,
        max_seq_len=max(seq_len, 32), lora=lora,
    )
    ssm_cfg = get_smoke_config("mamba2-130m").with_overrides(
        name="bench-mamba2-tiny", d_model=d_model, vocab_size=vocab,
        max_seq_len=max(seq_len, 32), lora=lora,
        ssm=SSMConfig(state_dim=16, head_dim=16, expand=2, chunk_size=8),
    )
    server_cfg = dense_cfg.with_overrides(name="bench-hetero-server")
    ds = make_banking77_like(
        vocab_size=vocab, seq_len=seq_len,
        total=60 * num_clients + pub_batch + 100, seed=0,
    )
    # client i: SSM for i < n_ssm, dense after — per-client random backbones
    # (the fully heterogeneous case: stacked frozens inside each bucket)
    fam = [ssm_cfg] * n_ssm + [dense_cfg] * n_dense

    def cohort():
        return [
            Client(i, fam[i], ds.subset(np.arange(i * 60, (i + 1) * 60)),
                   num_classes=ds.num_classes, seed=i, local_steps=4,
                   distill_steps=2)
            for i in range(num_clients)
        ]

    pub = jnp.asarray(ds.tokens[-pub_batch:])
    sim = ChannelSimulator(
        num_clients, ChannelConfig(bandwidth_hz=5e5, mean_snr_db=5.0), seed=0
    )
    sel = list(range(num_clients))
    states = sim.states_batched(0, sel)
    mk = dict(num_classes=ds.num_classes)

    # -- sequential reference + host server phase over dense stacks --------
    seq_engine = SequentialEngine(cohort(), dense_cfg)
    seq_server = Server(server_cfg, aggregation="adaptive",
                        distill_steps=server_distill_steps)

    def seq_round(bcast):
        phase = seq_engine.run_round(
            sel, pub, bcast, states, adaptive_k=True, send_h=True
        )
        k_g, h_g = seq_server.aggregate_dense(phase.dense, phase.h)
        seq_server.distill(pub, k_g, h_g)
        g_logits, g_h, bits = seq_server.broadcast(pub)
        jax.block_until_ready(g_logits)
        return BroadcastState(tokens=pub, logits=g_logits, h=g_h, bits=bits)

    # -- family-bucketed engine: per-bucket executables + union wire -------
    def hetero_engine():
        return HeteroFusedE2EEngine(
            cohort(),
            server=Server(server_cfg, aggregation="adaptive",
                          distill_steps=server_distill_steps),
            server_distill_steps=server_distill_steps, aggregation="adaptive",
            local_steps=4, distill_steps=2, **mk,
        )

    buck_engine = hetero_engine()

    def buck_round(bcast):
        buck_engine.run_round(sel, pub, bcast, states, adaptive_k=True, send_h=True)
        jax.block_until_ready(buck_engine._b_logits)
        return buck_engine.broadcast_state(pub)

    scan_engine = hetero_engine()
    sels = [sel] * scan_rounds
    pubs = [pub] * scan_rounds
    states_r = [sim.states_batched(r, sel) for r in range(scan_rounds)]

    def scan_block():
        scan_engine.run_rounds(sels, pubs, states_r, adaptive_k=True, send_h=True)
        jax.block_until_ready(scan_engine._b_logits)

    bc_seq = seq_round(None)
    bc_seq = seq_round(bc_seq)  # warm-up cold + warm executables
    bc_buck = buck_round(None)
    bc_buck = buck_round(bc_buck)
    scan_block()  # compile
    t_seq, t_buck, t_scan = [], [], []
    for _ in range(reps):
        t0 = time.time()
        bc_seq = seq_round(bc_seq)
        t_seq.append(time.time() - t0)
        t0 = time.time()
        bc_buck = buck_round(bc_buck)
        t_buck.append(time.time() - t0)
        t0 = time.time()
        scan_block()
        t_scan.append(time.time() - t0)
    us = {
        "hetero_seq": min(t_seq) * 1e6,
        "hetero_bucketed": min(t_buck) * 1e6,
        f"hetero_scan{scan_rounds}": min(t_scan) / scan_rounds * 1e6,
    }
    speedups = {
        "bucketed_vs_seq": us["hetero_seq"] / us["hetero_bucketed"],
        f"scan{scan_rounds}_vs_seq": us["hetero_seq"] / us[f"hetero_scan{scan_rounds}"],
    }
    shape = (
        f"C={n_ssm}ssm+{n_dense}dense;L2;d{d_model};V{vocab};T{seq_len};"
        f"P{pub_batch};steps=4+2;srv={server_distill_steps}"
    )

    if out_json:
        record = {
            "bench": "hetero_round",
            "shape": shape,
            "quick": quick,
            "reps": reps,
            "backend": jax.default_backend(),
            "cpu_count": os.cpu_count(),
            "us_per_round": {k: round(v) for k, v in us.items()},
            "speedups": {k: round(v, 2) for k, v in speedups.items()},
            "notes": (
                "hetero_seq = sequential per-client dispatches + host "
                "dense-stack server phase (the only pre-PR-5 execution for "
                "mixed fleets); hetero_bucketed = family-bucketed engine "
                "(one donated compiled client phase per family, union "
                "sparse wire, one compiled server phase); "
                f"hetero_scan{scan_rounds} = {scan_rounds} whole "
                "heterogeneous rounds per lax.scan dispatch, per-round "
                "figure.  Interleaved min-of-reps on this noisy 2-core CPU "
                "container."
            ),
        }
        with open(out_json, "w") as f:
            json.dump(record, f, indent=1)

    return [
        ("hetero_seq_round", us["hetero_seq"], shape),
        ("hetero_bucketed_round", us["hetero_bucketed"],
         f"{shape};vs_seq={speedups['bucketed_vs_seq']:.2f}x"),
        (f"hetero_scan{scan_rounds}_round", us[f"hetero_scan{scan_rounds}"],
         f"{shape};vs_seq={speedups[f'scan{scan_rounds}_vs_seq']:.2f}x"),
    ]


def _assert_dequant_agg_dense_stack_free(n: int, rows: int, vocab: int, k_cap: int) -> int:
    """Same trace inspection as :func:`_assert_agg_dense_stack_free`, for the
    QUANTIZED route: the dequantize-fused aggregation (int8 wire + per-row
    scale in, (B, V) teacher out) must reconstruct float values only inside
    the O(N·B·k_cap) working set, never as an (N, rows, V) stack."""
    from repro.core.aggregation import aggregate_wire, max_intermediate_elems
    from repro.core.topk import QuantizedWire

    def agg(values, scale, indices, mask, n_tx):
        wire = QuantizedWire(
            values=values, scale=scale, indices=indices, mask=mask, vocab=vocab
        )
        return aggregate_wire(wire, "adaptive", num_transmitters=n_tx)

    jaxpr = jax.make_jaxpr(agg)(
        jnp.zeros((n, rows, k_cap), jnp.int8), jnp.ones((n, rows), jnp.float32),
        jnp.zeros((n, rows, k_cap), jnp.int32),
        jnp.zeros((n, rows, k_cap), bool), jnp.int32(n),
    )
    worst = max_intermediate_elems(jaxpr)
    dense_stack = n * rows * vocab
    assert worst < dense_stack, (
        f"dequant-fused aggregation materialised {worst} elements >= the "
        f"dense (N, B, V) stack's {dense_stack}"
    )
    return worst


def bench_quant(quick: bool = True, out_json: str | None = None):
    """Quantized int8 wire vs the float16 wire (writes BENCH_quant[.quick].json).

    Three readings:

    * equal-shape pricing — the engines' single accounting source
      (``make_upload_payload``) at the SAME (num_samples, k): the int8 wire
      must be strictly cheaper on the air.
    * fixed-SNR fed runs — two identical fused_e2e ``run_federated`` runs
      (float vs ``quantize_wire=True``) on the same constrained channel at a
      fixed nominal SNR: bytes/round, the larger adaptive mean k the 8-bit
      entry pricing buys back at the same Shannon budget, and the accuracy
      trajectory.
    * dequant-fused proof — trace inspection that the QuantizedWire
      aggregation route stays dense-stack-free at bench shapes.
    """
    from repro.configs.base import LoRAConfig
    from repro.configs.gpt2_paper import REDUCED_CLIENT, REDUCED_SERVER
    from repro.core import ChannelConfig
    from repro.data import make_banking77_like
    from repro.fed import FedConfig, run_federated
    from repro.fed.client import make_upload_payload

    vocab = 256 if quick else 4096
    rounds = 2 if quick else 3
    lora = LoRAConfig(rank=4, alpha=32.0, dropout=0.0, targets=("q", "v", "head"))
    client = REDUCED_CLIENT.with_overrides(
        num_layers=2, d_model=64, num_heads=2, num_kv_heads=2, d_ff=128,
        vocab_size=vocab, max_seq_len=32, lora=lora,
    )
    server = REDUCED_SERVER.with_overrides(
        num_layers=2, d_model=96, num_heads=2, num_kv_heads=2, d_ff=192,
        vocab_size=vocab, max_seq_len=32, lora=lora,
    )
    ds = make_banking77_like(vocab_size=vocab, seq_len=12, total=500, seed=0)
    # constrained fixed-SNR uplink: the adaptive k is budget-bound, so the
    # cheaper 8-bit entries show up as MORE transmitted entries per round
    chan = ChannelConfig(bandwidth_hz=4e4, mean_snr_db=5.0)

    def cfg(quantize):
        return FedConfig(
            method="adald", engine="fused_e2e", num_clients=4,
            clients_per_round=2, rounds=rounds, public_size=64,
            public_batch=16, eval_size=64, local_steps=2, distill_steps=1,
            server_distill_steps=2, seed=0, channel=chan, pretrain_steps=0,
            quantize_wire=quantize,
        )

    t0 = time.time()
    flt = run_federated(client, server, ds, cfg(False))
    qnt = run_federated(client, server, ds, cfg(True))
    wall_s = time.time() - t0

    def summarise(run):
        up = [r.uplink_bytes for r in run.ledger.rounds]
        return {
            "mean_k": round(float(np.mean(run.mean_k)), 1),
            "uplink_bytes_per_round": round(float(np.mean(up))),
            "uplink_bytes_total": round(float(np.sum(up))),
            "final_server_acc": round(float(run.server_acc[-1]), 4),
            "server_acc": [round(float(a), 4) for a in run.server_acc],
        }

    f_sum, q_sum = summarise(flt), summarise(qnt)

    # equal-shape pricing through the engines' single accounting source: the
    # quant run's largest realized k, priced at 16-bit vs 8-bit entries
    k_eq = int(max(max(ks) for ks in qnt.per_client_k))
    n_samples = 64  # the runs' public_size
    fpay, _ = make_upload_payload(
        client, 0, n_samples, k_eq, send_h=True, value_bits=16,
        snr_db=float(chan.mean_snr_db),
    )
    qpay, _ = make_upload_payload(
        client, 0, n_samples, k_eq, send_h=True, value_bits=16,
        snr_db=float(chan.mean_snr_db), quantize=True,
    )
    assert qpay.spec.uplink_bits < fpay.spec.uplink_bits, (
        "int8 wire must be strictly cheaper than the float wire at equal shape"
    )

    agg_n, agg_rows, agg_vocab, agg_k_cap = 10, 64, 8192, 256
    max_elems = _assert_dequant_agg_dense_stack_free(
        agg_n, agg_rows, agg_vocab, agg_k_cap
    )

    savings = {
        "float_vs_quant_bytes_equal_k": round(
            fpay.spec.uplink_bits / qpay.spec.uplink_bits, 2
        ),
        "quant_vs_float_mean_k": round(q_sum["mean_k"] / f_sum["mean_k"], 2),
    }
    shape = f"C=4x2;L2;d64/96;V{vocab};T12;P64;R{rounds};fused_e2e"

    if out_json:
        record = {
            "bench": "quant_wire",
            "shape": shape,
            "quick": quick,
            "backend": jax.default_backend(),
            "cpu_count": os.cpu_count(),
            "channel": {"bandwidth_hz": chan.bandwidth_hz,
                        "mean_snr_db": chan.mean_snr_db},
            "float": f_sum,
            "quant": q_sum,
            "equal_shape": {
                "k": k_eq,
                "num_samples": n_samples,
                "float_uplink_bytes": round(fpay.spec.uplink_bytes),
                "quant_uplink_bytes": round(qpay.spec.uplink_bytes),
            },
            "aggregation": {
                "max_agg_intermediate_elems": max_elems,
                "dense_stack_elems": agg_n * agg_rows * agg_vocab,
                "agg_dense_stack_free": True,  # asserted above
            },
            "speedups": savings,
            "wall_s": round(wall_s, 1),
            "notes": (
                "Two identical fused_e2e run_federated runs on the same "
                "constrained fixed-nominal-SNR channel: float (16-bit "
                "entries) vs quantize_wire=True (int8 entries + per-row f32 "
                "scale, h kept at 16 bits).  equal_shape prices the quant "
                "run's largest realized k through make_upload_payload at "
                "both widths — the engines' single accounting source.  "
                "quant_vs_float_mean_k > 1 is the budget buy-back: cheaper "
                "entries -> larger adaptive k at the SAME Shannon budget.  "
                "agg_dense_stack_free re-proves the dequantize-fused "
                "aggregation route on the int8 wire."
            ),
        }
        with open(out_json, "w") as f:
            json.dump(record, f, indent=1)

    return [
        ("quant_float_uplink_bytes_per_round", f_sum["uplink_bytes_per_round"],
         f"{shape};mean_k={f_sum['mean_k']}"),
        ("quant_int8_uplink_bytes_per_round", q_sum["uplink_bytes_per_round"],
         f"{shape};mean_k={q_sum['mean_k']}"
         f";equal_k_savings={savings['float_vs_quant_bytes_equal_k']:.2f}x"),
    ]


if __name__ == "__main__":
    quick = "--quick" in sys.argv
    round_only = "--round-only" in sys.argv
    engine_only = "--engine-only" in sys.argv
    hetero_only = "--hetero-only" in sys.argv
    quant_only = "--quant-only" in sys.argv
    any_only = round_only or engine_only or hetero_only or quant_only
    # quick runs get their own file so they never clobber the committed
    # full-size record that README cites
    suffix = "quick.json" if quick else "json"
    jobs = []
    if engine_only or not any_only:
        jobs.append((bench, os.path.join(_REPO_ROOT, f"BENCH_engine.{suffix}")))
    if round_only or not any_only:
        jobs.append((bench_round, os.path.join(_REPO_ROOT, f"BENCH_round.{suffix}")))
    if hetero_only or not any_only:
        jobs.append((bench_hetero, os.path.join(_REPO_ROOT, f"BENCH_hetero.{suffix}")))
    if quant_only or not any_only:
        jobs.append((bench_quant, os.path.join(_REPO_ROOT, f"BENCH_quant.{suffix}")))
    for fn, out in jobs:
        rows = fn(quick=quick, out_json=out)
        for name, us, derived in rows:
            print(f"{name},{us:.0f},{derived}")
        with open(out) as f:
            rec = json.load(f)
        for k, v in rec["speedups"].items():
            print(f"{k}: {v:.2f}x")
        print(f"-> {out}")
