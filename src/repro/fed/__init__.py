from repro.fed.client import Client, ClientUpload
from repro.fed.rounds import METHODS, FedConfig, FedRun, run_federated
from repro.fed.server import Server

__all__ = ["Client", "ClientUpload", "Server", "METHODS", "FedConfig", "FedRun", "run_federated"]
