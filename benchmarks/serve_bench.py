"""Multi-tenant serving benchmark (PR 10) — writes BENCH_serve[.quick].json.

The claim under test: ONE donated jitted decode step + ONE shared frozen
backbone serve a mixed batch of tenants (each request applying its own
LoRA adapter via the slab gather) at (within noise of) single-adapter
throughput, bit-identically to running each request alone with its
adapter merged the classic way.  Three regimes:

* ``single_adapter``        — the pre-redesign layout: one adapter merged
                              into the params, batch B, classic decode.
                              The throughput baseline.
* ``stacked_multi_tenant``  — B DISTINCT tenants in one batch through the
                              stacked decode step (adapter slab + per-
                              request int32 slot gather), warm cache.
                              Parity-probed bitwise, row-by-row, against
                              equal-batch classic merged-adapter decode.
* ``cache_thrash``          — more tenants than device slots: every
                              segment rotates the batch to 8 cold tenants,
                              so each attach pages 8 misses through LRU
                              eviction.  Throughput INCLUDES the host->
                              device paging, isolating the paging tax.

benchmarks/check_bench.py gates on this record: parity flag true,
adapters/batch >= 8, one stacked decode executable, and stacked steady
throughput >= 0.9x single-adapter at equal batch.

Run:  PYTHONPATH=src python benchmarks/serve_bench.py [--quick]
"""

from __future__ import annotations

import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

_REPO_ROOT = os.path.join(os.path.dirname(__file__), "..")

BATCH = 8          # requests per decode step == adapters per batch
SLOTS = 8          # device adapter-cache slots
TENANTS = 24       # thrash fleet: 3x oversubscribed vs SLOTS
PROMPT = 8
PROBE = 8          # parity-probe decode length (bitwise, always run)


class _RandomSource:
    """Synthetic tenant fleet: tenant cid = adapter with randomized A AND
    B (fresh-init B is zero — every tenant's delta would vanish and the
    parity probe would be vacuous)."""

    def __init__(self, params, num_adapters: int, seed: int = 7):
        from repro.lora import map_lora, split_lora

        self._lora, _ = split_lora(params)
        self.num_adapters = int(num_adapters)
        self._seed = seed
        self._map_lora = map_lora

    def lora_row(self, cid: int):
        key = jax.random.fold_in(jax.random.PRNGKey(self._seed), int(cid))
        counter = [0]

        def rnd(x):
            counter[0] += 1
            k = jax.random.fold_in(key, counter[0])
            return 0.05 * jax.random.normal(k, x.shape).astype(x.dtype)

        return self._map_lora(rnd, self._lora)


def _build():
    from repro.configs.base import LoRAConfig
    from repro.configs.gpt2_paper import REDUCED_CLIENT
    from repro.models import init as model_init

    lora = LoRAConfig(rank=4, alpha=32.0, dropout=0.0,
                      targets=("q", "v", "o", "head"))
    # big enough that the backbone dominates a decode step (at toy widths
    # the unmerged per-request LoRA einsums are a visible fraction)
    cfg = REDUCED_CLIENT.with_overrides(
        num_layers=4, d_model=256, num_heads=4, num_kv_heads=4, d_ff=512,
        vocab_size=1024, max_seq_len=256, lora=lora,
    )
    params = model_init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _session(cfg, params, tokens, *, adapters=None):
    from repro.serve import ServeConfig, ServeSession

    scfg = ServeConfig(model=cfg, batch=BATCH,
                       cache_len=PROMPT + PROBE + tokens + 8)
    return ServeSession(scfg, params, adapters=adapters)


def _prompts(cfg, seed=3):
    rng = np.random.default_rng(seed)
    return rng.integers(0, cfg.vocab_size, (BATCH, PROMPT)).astype(np.int32)


def _burst(sess, prompts, tokens):
    """One timed decode burst (prefill untimed)."""
    sess.prefill(prompts)
    t0 = time.perf_counter()
    sess.decode(tokens)
    return time.perf_counter() - t0


def _regime(best_s, tokens, sess, reps):
    steady = best_s / tokens
    return {
        "tok_s": round(BATCH / steady, 1),
        "ms_per_step": round(steady * 1e3, 3),
        "compile_first_step_s": round(
            max(sess.stats()["first_step_s"].values()), 3
        ),
        "reps": reps,
    }


def _parity_probe(cfg, params, source, stacked_toks, stacked_logits, prompts):
    """Bitwise at EQUAL batch: stacked row b == row b of a classic decode
    with tenant b's adapter merged into the params the pre-redesign way.
    Equal batch isolates what the adapter machinery can and must
    guarantee — the per-request slab gather adds ZERO deviation over
    merge_lora — because XLA is not bit-stable across batch SIZES at this
    width even with no adapters in play (~1 ulp on CPU; measured).  The
    strict solo batch-1 claim is proven in tests/test_serve.py at a width
    where the backbone itself is batch-stable."""
    from repro.lora import merge_lora, split_lora
    from repro.models import init_cache
    from repro.serve import make_decode_step

    _, frozen = split_lora(params)
    step = jax.jit(make_decode_step(cfg))  # ONE compile, reused per tenant
    ok = True
    for b in range(BATCH):
        merged = merge_lora(source.lora_row(b), frozen)
        cache = init_cache(cfg, BATCH, PROMPT + PROBE + 2)
        logits = None
        for t in range(PROMPT):
            logits, cache = step(merged, cache, prompts[:, t])
        rows = []
        for _ in range(PROBE):
            nxt = jnp.argmax(logits, axis=-1)
            rows.append(int(np.asarray(nxt)[b]))
            logits, cache = step(merged, cache, nxt)
        ok = ok and rows == stacked_toks[b, :PROBE].tolist()
        ok = ok and np.array_equal(np.asarray(logits)[b], stacked_logits[b])
    return bool(ok)


def _thrash(cfg, params, tokens, segments):
    """Rotate the batch to 8 cold tenants every segment: each attach pages
    BATCH misses through LRU eviction.  Wall-clock includes the paging."""
    from repro.lora import lora_template
    from repro.serve import AdapterCache

    source = _RandomSource(params, TENANTS)
    cache = AdapterCache(source, like=lora_template(params), slots=SLOTS)
    sess = _session(cfg, params, tokens, adapters=cache)
    prompts = _prompts(cfg)

    def segment(s):
        ids = [(s * BATCH + i) % TENANTS for i in range(BATCH)]
        sess.attach(ids)
        sess.prefill(prompts)
        sess.decode(tokens)

    segment(0)  # warmup: compiles the stacked step + cold-fills the cache
    cache.reset_stats()
    t0 = time.time()
    for s in range(1, segments + 1):
        segment(s)
    wall = time.time() - t0
    total = segments * BATCH * (PROMPT + tokens)
    return {
        "tok_s_incl_paging": round(total / wall, 1),
        "adapters_per_batch": BATCH,
        "distinct_tenants": TENANTS,
        "slots": SLOTS,
        "segments_timed": segments,
        "cache": sess.adapters.stats.as_dict(),
    }


def bench_serve(quick: bool = True, out_json: str | None = None):
    from repro.lora import lora_template, merge_lora, split_lora
    from repro.serve import AdapterCache

    cfg, params = _build()
    tokens = 24 if quick else 64
    segments = 4 if quick else 8
    reps = 5 if quick else 7
    source = _RandomSource(params, BATCH)
    prompts = _prompts(cfg)

    # -- single_adapter vs stacked_multi_tenant, PAIRED bursts ------------
    # single: one tenant merged classic (pre-redesign layout), batch B;
    # stacked: B distinct tenants through the one stacked decode step.
    # Bursts are interleaved single/stacked per rep so a localized stall
    # on this noisy container hits both regimes, not just one side of the
    # throughput ratio; min-of-reps per regime.
    _, frozen = split_lora(params)
    merged = merge_lora(source.lora_row(0), frozen)
    s_sess = _session(cfg, merged, tokens)
    cache = AdapterCache(source, like=lora_template(params), slots=SLOTS)
    sess = _session(cfg, params, tokens, adapters=cache)
    sess.attach(list(range(BATCH)))
    _burst(s_sess, prompts, tokens)  # compile + warmup, both modes
    _burst(sess, prompts, tokens)
    best_single = best_stacked = float("inf")
    for _ in range(reps):
        best_single = min(best_single, _burst(s_sess, prompts, tokens))
        best_stacked = min(best_stacked, _burst(sess, prompts, tokens))
    single = _regime(best_single, tokens, s_sess, reps)
    single["adapters_per_batch"] = 1
    stacked = _regime(best_stacked, tokens, sess, reps)
    stacked["adapters_per_batch"] = BATCH
    stacked["cache"] = sess.adapters.stats.as_dict()

    # parity probe: a PROBE-length stacked decode (reuses the SAME compiled
    # step) vs each tenant served alone at batch 1, bitwise per row
    sess.attach(list(range(BATCH)))
    sess.prefill(prompts)
    ptoks, plogits = sess.decode(PROBE)
    stacked["decode_executables"] = sess.stats()["executables"]["stacked"]
    parity = _parity_probe(cfg, params, source, ptoks, np.asarray(plogits),
                           prompts)

    # -- cache_thrash: oversubscribed fleet, paging on every attach -------
    thrash = _thrash(cfg, params, tokens, segments)

    ratio = round(stacked["tok_s"] / single["tok_s"], 3)
    shape = (f"B{BATCH};L{cfg.num_layers};d{cfg.d_model};V{cfg.vocab_size};"
             f"P{PROMPT};T{tokens};rank{cfg.lora.rank};slots{SLOTS}")

    if out_json:
        record = {
            "bench": "serve",
            "shape": shape,
            "quick": quick,
            "backend": jax.default_backend(),
            "cpu_count": os.cpu_count(),
            "parity": {
                "multi_tenant_bit_identical": parity,
                "adapters_per_batch": BATCH,
                "probe_tokens": PROBE,
                "baseline": (
                    "equal-batch classic merge_lora decode (row b of an "
                    "all-tenant-b batch); solo batch-1 parity is proven in "
                    "tests/test_serve.py at a batch-stable width"
                ),
            },
            "regimes": {
                "single_adapter": single,
                "stacked_multi_tenant": stacked,
                "cache_thrash": thrash,
            },
            "speedups": {"stacked_vs_single": ratio},
            "notes": (
                "Steady-state decode throughput = best of timed decode "
                "bursts after a compile + warmup burst, with single/"
                "stacked bursts INTERLEAVED per rep so a localized stall "
                "on this noisy CPU container hits both sides of the "
                "throughput ratio (the pre-redesign script folded XLA "
                "compile into tok/s).  single_adapter merges one "
                "tenant into the "
                "params (pre-redesign layout); stacked_multi_tenant "
                f"serves {BATCH} DISTINCT tenants per batch via the "
                "adapter-slab gather in ONE compiled decode step, parity-"
                "probed bitwise per row against equal-batch classic "
                "merge_lora decode (XLA is not bit-stable across batch "
                "SIZES at this width even adapter-free, so equal batch is "
                "the honest claim here; solo batch-1 parity is proven in "
                "tests/test_serve.py).  "
                "cache_thrash oversubscribes the device slots "
                f"({TENANTS} tenants, {SLOTS} slots) and rotates the "
                "batch to 8 cold tenants per segment, so wall-clock "
                "includes host->device adapter paging + LRU eviction."
            ),
        }
        with open(out_json, "w") as f:
            json.dump(record, f, indent=1)

    return [
        ("serve_single_adapter", 1e6 * BATCH / single["tok_s"],
         f"{shape};tok_s={single['tok_s']}"),
        ("serve_stacked_8tenant", 1e6 * BATCH / stacked["tok_s"],
         f"{shape};tok_s={stacked['tok_s']};vs_single={ratio}x;"
         f"parity={parity}"),
        ("serve_cache_thrash", 1e6 * BATCH / thrash["tok_s_incl_paging"],
         f"{shape};tok_s={thrash['tok_s_incl_paging']};"
         f"misses={thrash['cache']['misses']};"
         f"evictions={thrash['cache']['evictions']}"),
    ]


if __name__ == "__main__":
    quick = "--quick" in sys.argv
    suffix = "quick.json" if quick else "json"
    out = os.path.join(_REPO_ROOT, f"BENCH_serve.{suffix}")
    for name, us, derived in bench_serve(quick=quick, out_json=out):
        print(f"{name},{us:.0f},{derived}")
    with open(out) as f:
        rec = json.load(f)
    print(f"parity (8-tenant bitwise vs classic merged): "
          f"{rec['parity']['multi_tenant_bit_identical']}")
    print(f"stacked vs single throughput: "
          f"{rec['speedups']['stacked_vs_single']:.2f}x")
    print(f"-> {out}")
