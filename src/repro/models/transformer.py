"""Transformer stacks: decoder-only, encoder-decoder, hybrid (Jamba-style).

Layer layout is a repeating **period** (DESIGN §4):

  * uniform families (dense/moe/ssm/vlm): period = 1;
  * hybrid: period = lcm(attn_every, moe_every) — Jamba 1.5's 1-attn-per-8
    with MoE every 2 gives an 8-layer period repeated num_layers/8 times.

Parameters for each position-in-period are **stacked over repeats** and the
stack runs under ``jax.lax.scan`` (one compiled period regardless of depth —
72-layer Jamba compiles like an 8-layer model).  ``cfg.remat=True`` wraps the
scan body in ``jax.checkpoint`` for activation rematerialisation.

Caches (decode) follow the same layout: a period-dict of per-position cache
pytrees, each stacked over repeats, scanned alongside the params.
"""

from __future__ import annotations

import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.attention import (
    attn_apply,
    attn_init,
    cross_attn_apply,
    init_kv_cache,
)
from repro.models.layers import mlp_apply, mlp_init, norm_apply, norm_init
from repro.models.moe import moe_apply, moe_init
from repro.models.ssm import init_ssm_cache, ssm_apply, ssm_init

__all__ = ["StackState", "period_of", "stack_init", "stack_apply", "init_stack_cache"]

import os as _os

_UNROLL = _os.environ.get("REPRO_UNROLL", "0") == "1"


class StackState(NamedTuple):
    """Carry through the layer scan."""

    x: jax.Array  # (B, S, D) activations
    moe_aux: jax.Array  # () accumulated load-balance loss
    lora_h: jax.Array | None  # (B, r) most recent LoRA projection or None


def period_of(cfg: ModelConfig) -> int:
    if cfg.family != "hybrid":
        return 1
    p = cfg.attn_every
    if cfg.moe is not None:
        p = math.lcm(p, cfg.moe_every)
    assert cfg.num_layers % p == 0, (
        f"{cfg.name}: num_layers={cfg.num_layers} not divisible by period {p}"
    )
    return p


def _layer_kinds(cfg: ModelConfig, j: int) -> tuple[str, str | None]:
    """(mixer kind, mlp kind) for position-in-period j."""
    mixer = "attn" if cfg.is_attention_layer(j) else "ssm"
    if cfg.family == "ssm":
        return mixer, None  # Mamba2 stacks: no separate MLP
    mlp = "moe" if cfg.is_moe_layer(j) else "dense"
    return mixer, mlp


def _layer_init(rng: jax.Array, cfg: ModelConfig, j: int, *, cross: bool) -> dict:
    mixer, mlp = _layer_kinds(cfg, j)
    keys = jax.random.split(rng, 8)
    params: dict[str, Any] = {"norm1": norm_init(cfg.d_model, kind=cfg.norm, dtype=cfg.param_dtype)}
    if mixer == "attn":
        params["attn"] = attn_init(keys[0], cfg)
        if cfg.lora is not None:
            params["lora"] = _lora_init(keys[1], cfg)
    else:
        params["ssm"] = ssm_init(keys[0], cfg)
    if cross:
        params["norm_x"] = norm_init(cfg.d_model, kind=cfg.norm, dtype=cfg.param_dtype)
        params["cross"] = attn_init(keys[2], cfg)
    if mlp is not None:
        params["norm2"] = norm_init(cfg.d_model, kind=cfg.norm, dtype=cfg.param_dtype)
        params["mlp"] = mlp_init(
            keys[3], cfg.d_model, cfg.d_ff, activation=cfg.activation, use_bias=cfg.use_bias, dtype=cfg.param_dtype
        ) if mlp == "dense" else moe_init(keys[3], cfg)
    return params


def _lora_init(rng: jax.Array, cfg: ModelConfig) -> dict:
    """LoRA A/B for the configured attention targets (default q, v)."""
    lc = cfg.lora
    hd = cfg.head_dim
    out_dims = {"q": cfg.num_heads * hd, "k": cfg.num_kv_heads * hd, "v": cfg.num_kv_heads * hd, "o": cfg.d_model}
    params = {}
    attn_targets = [t for t in lc.targets if t in out_dims]  # 'head' lives at top level
    keys = jax.random.split(rng, max(1, len(attn_targets)))
    for key, tgt in zip(keys, attn_targets):
        a = jax.random.normal(key, (cfg.d_model, lc.rank), jnp.float32) * (1.0 / cfg.d_model**0.5)
        params[tgt] = {
            "A": a.astype(jnp.dtype(cfg.param_dtype)),
            "B": jnp.zeros((lc.rank, out_dims[tgt]), jnp.dtype(cfg.param_dtype)),
        }
    return params


def stack_init(
    rng: jax.Array, cfg: ModelConfig, num_layers: int, *, cross: bool = False, causal: bool = True
) -> dict:
    """Init a stack as a period-dict of repeat-stacked layer params."""
    del causal  # same params either way
    p = period_of(cfg)
    if num_layers != cfg.num_layers:
        p = 1  # encoder stacks are uniform
    repeats = num_layers // p
    out = {}
    for j in range(p):
        keys = jax.random.split(jax.random.fold_in(rng, j), repeats)
        out[f"pos{j}"] = jax.vmap(lambda k: _layer_init(k, cfg, j, cross=cross))(keys)
    return out


def _apply_one(
    params: dict,
    state: StackState,
    cfg: ModelConfig,
    j: int,
    *,
    positions: jax.Array,
    window: int | None,
    cache: Any | None,
    enc_out: jax.Array | None,
    causal: bool,
) -> tuple[StackState, Any | None]:
    mixer, mlp = _layer_kinds(cfg, j)
    x = state.x
    moe_aux = state.moe_aux
    lora_h = state.lora_h

    h_in = norm_apply(params["norm1"], x, kind=cfg.norm)
    if mixer == "attn":
        y, new_cache, h = attn_apply(
            params["attn"],
            h_in,
            cfg,
            positions=positions,
            window=window,
            cache=cache,
            lora=params.get("lora"),
            causal=causal,
        )
        if h is not None:
            lora_h = jnp.mean(h, axis=1)  # (B, r) — pooled LoRA projection
    else:
        y, new_cache = ssm_apply(params["ssm"], h_in, cfg, cache=cache)
    x = x + y

    if enc_out is not None and "cross" in params:
        cx = norm_apply(params["norm_x"], x, kind=cfg.norm)
        x = x + cross_attn_apply(params["cross"], cx, enc_out, cfg)

    if mlp is not None:
        h2 = norm_apply(params["norm2"], x, kind=cfg.norm)
        if mlp == "moe":
            y2, aux = moe_apply(params["mlp"], h2, cfg)
            moe_aux = moe_aux + aux
        else:
            y2 = mlp_apply(params["mlp"], h2, activation=cfg.activation, compute_dtype=cfg.compute_dtype)
        x = x + y2

    return StackState(x=x, moe_aux=moe_aux, lora_h=lora_h), new_cache


def init_stack_cache(
    cfg: ModelConfig, num_layers: int, batch: int, cache_len: int, *, window: int | None = None
) -> dict:
    """Period-dict of repeat-stacked caches for decode."""
    p = period_of(cfg)
    if num_layers != cfg.num_layers:
        p = 1
    repeats = num_layers // p
    c = min(cache_len, window) if window is not None else cache_len
    out = {}
    for j in range(p):
        mixer, _ = _layer_kinds(cfg, j)
        if mixer == "attn":
            one = init_kv_cache(cfg, batch, c)
        else:
            one = init_ssm_cache(cfg, batch)
        out[f"pos{j}"] = jax.tree.map(lambda a: jnp.broadcast_to(a, (repeats,) + a.shape), one)
    return out


def stack_apply(
    stack_params: dict,
    x: jax.Array,
    cfg: ModelConfig,
    num_layers: int,
    *,
    positions: jax.Array,
    window: int | None = None,
    caches: dict | None = None,
    enc_out: jax.Array | None = None,
    causal: bool = True,
) -> tuple[StackState, dict | None]:
    """Run the full stack.  Returns (final state, updated caches or None)."""
    p = period_of(cfg)
    if num_layers != cfg.num_layers:
        p = 1
    repeats = num_layers // p

    lora_h0 = None
    if cfg.lora is not None and any(
        _layer_kinds(cfg, j)[0] == "attn" for j in range(p)
    ):
        lora_h0 = jnp.zeros((x.shape[0], cfg.lora.rank), jnp.dtype(cfg.compute_dtype))
    state0 = StackState(x=x, moe_aux=jnp.zeros((), jnp.float32), lora_h=lora_h0)

    def body(state, xs):
        params_slice, cache_slice = xs
        new_caches = {}
        for j in range(p):
            cache_j = cache_slice[f"pos{j}"] if cache_slice is not None else None

            def one(params_j, state, cache_j, j=j):
                return _apply_one(
                    params_j, state, cfg, j,
                    positions=positions, window=window, cache=cache_j,
                    enc_out=enc_out, causal=causal,
                )

            if cfg.remat and p > 1:
                # nested remat: periods with many sublayers (jamba: 8) would
                # otherwise hold every sublayer's residuals at once during
                # the period's backward (§Perf iteration 6)
                one = jax.checkpoint(one, prevent_cse=False)
            state, nc = one(params_slice[f"pos{j}"], state, cache_j)
            if nc is not None:
                new_caches[f"pos{j}"] = nc
        return state, (new_caches if new_caches else None)

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)

    if _UNROLL:
        # cost-mode (REPRO_UNROLL=1): python loop so HLO cost analysis sees
        # every repeat (XLA counts while bodies once; see launch/dryrun.py)
        state = state0
        new_caches_list = []
        for r in range(repeats):
            params_r = jax.tree.map(lambda a: a[r], stack_params)
            cache_r = jax.tree.map(lambda a: a[r], caches) if caches is not None else None
            state, nc = body(state, (params_r, cache_r))
            if nc is not None:
                new_caches_list.append(nc)
        if caches is None:
            return state, None
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *new_caches_list)
        return state, stacked

    if caches is None:
        # scan can't carry a None in xs leaves; substitute per-step None via
        # a length marker: replicate None structure by scanning params only.
        def body_nocache(state, params_slice):
            s, _ = body(state, (params_slice, None))
            return s, None

        final, _ = jax.lax.scan(body_nocache, state0, stack_params, length=repeats)
        return final, None

    # Decode: carry the stacked caches through a fori_loop and update slices
    # in place.  A scan emitting new caches as ys holds BOTH the old stack
    # (xs) and the new stack (ys) plus in-flight copies — ~3x cache in temp
    # memory at decode_32k (§Perf iteration 9); while-loop carries alias.
    def body_carry(r, carry):
        state, caches_c = carry
        params_r = jax.tree.map(lambda a: jax.lax.dynamic_index_in_dim(a, r, 0, keepdims=False), stack_params)
        cache_r = jax.tree.map(lambda a: jax.lax.dynamic_index_in_dim(a, r, 0, keepdims=False), caches_c)
        state, new_r = body(state, (params_r, cache_r))
        caches_c = jax.tree.map(
            lambda full, n: jax.lax.dynamic_update_index_in_dim(full, n, r, 0),
            caches_c,
            new_r,
        )
        return state, caches_c

    final, new_caches = jax.lax.fori_loop(0, repeats, body_carry, (state0, caches))
    return final, new_caches
