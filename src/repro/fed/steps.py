"""Jitted step functions for the FL runtime (Algorithm 1).

Task convention (paper §IV): decoder-only LM fine-tuned for Banking77
intent detection — class logits are the LM-head logits over the first
``num_classes`` vocab ids at the LAST sequence position.  Distillation
(paper eqs. 9-10) operates on the FULL last-position vocab logits (the
high-dimensional vector the adaptive Top-k sparsifies).

All steps train the LoRA subset only (paper §II-A): gradients flow through
``split_lora`` so the frozen backbone never enters the optimizer.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.distill import total_distill_loss
from repro.core.topk import topk_mask_dynamic
from repro.lora import merge_lora, split_lora
from repro.models import forward
from repro.optim import AdamWState, adamw_init, adamw_update

__all__ = [
    "class_logits",
    "public_logits",
    "last_logits",
    "make_finetune_step",
    "make_distill_step",
    "make_batched_finetune_step",
    "make_batched_distill_step",
    "make_batched_public_logits",
    "make_fused_round_fn",
    "make_eval_fn",
    "init_lora_opt",
]


def class_logits(logits_last: jax.Array, num_classes: int) -> jax.Array:
    """(B, V) last-position logits -> (B, num_classes) class readout."""
    return logits_last[..., :num_classes]


def last_logits(params, cfg: ModelConfig, batch: dict, *, last_only: bool = True):
    """(B, V) last-position logits + Aux, via the cheap head when enabled.

    ``last_only=True`` (default) computes the LM head on the final hidden
    state only — a ~seq_len× cut in head FLOPs/memory, which dominates at
    the paper's 50k+ vocabularies; ``False`` keeps the seed behaviour of
    materialising (B, T, V) and slicing (the PR-1 reference, benchmarked
    against in benchmarks/engine_bench.py).
    """
    if last_only:
        return forward(params, cfg, batch, last_only=True)
    logits, aux = forward(params, cfg, batch)
    return logits[:, -1, :], aux


@functools.partial(jax.jit, static_argnames=("cfg", "last_only"))
def public_logits(params, cfg: ModelConfig, tokens: jax.Array, *, last_only: bool = True):
    """Last-position vocab logits + pooled LoRA projection on a public batch.

    Returns (logits (B, V), h (B, r) or None) — the client/server upload
    content (Algorithm 1 lines 4, 14).
    """
    logits, aux = last_logits(params, cfg, {"tokens": tokens}, last_only=last_only)
    return logits, aux.lora_h


def init_lora_opt(params, cfg: ModelConfig) -> AdamWState:
    lora, _ = split_lora(params)
    return adamw_init(lora, state_dtype=cfg.optimizer_state_dtype)


def _finetune_loss_fn(cfg: ModelConfig, num_classes: int, last_only: bool = True) -> Callable:
    """loss(lora, frozen, batch) -> (nll + moe_aux, acc) — the shared core
    of the sequential step, the batched cohort step and the fused round."""

    def loss_fn(lora, frozen, batch):
        params = merge_lora(lora, frozen)
        last, aux = last_logits(params, cfg, {"tokens": batch["tokens"]}, last_only=last_only)
        cls = class_logits(last, num_classes)
        logp = jax.nn.log_softmax(cls.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, batch["labels"][:, None], axis=-1).mean()
        acc = jnp.mean((jnp.argmax(cls, -1) == batch["labels"]).astype(jnp.float32))
        return nll + 0.01 * aux.moe_aux, acc

    return loss_fn


def _finetune_step_fn(
    cfg: ModelConfig, num_classes: int, lr: float, weight_decay: float, last_only: bool = True
) -> Callable:
    """Unjitted single-client fine-tune step over merged params."""

    loss_fn = _finetune_loss_fn(cfg, num_classes, last_only)

    def step(params, opt, batch):
        lora, frozen = split_lora(params)
        (loss, acc), grads = jax.value_and_grad(loss_fn, has_aux=True)(lora, frozen, batch)
        new_lora, new_opt = adamw_update(
            grads, opt, lora, lr=lr, weight_decay=weight_decay
        )
        return merge_lora(new_lora, frozen), new_opt, {"loss": loss, "acc": acc}

    return step


@functools.lru_cache(maxsize=64)
def make_finetune_step(
    cfg: ModelConfig,
    num_classes: int,
    *,
    lr: float = 1e-3,
    weight_decay: float = 1e-3,
    last_only: bool = True,
) -> Callable:
    """Supervised local fine-tuning on private data (paper eq. 2), LoRA-only.

    step(params, opt, batch{tokens,labels}) -> (params, opt, metrics)
    """
    return jax.jit(_finetune_step_fn(cfg, num_classes, lr, weight_decay, last_only))


@functools.lru_cache(maxsize=64)
def make_batched_finetune_step(
    cfg: ModelConfig,
    num_classes: int,
    *,
    lr: float = 1e-3,
    weight_decay: float = 1e-3,
    shared_backbone: bool = True,
    last_only: bool = True,
) -> Callable:
    """One fine-tune update for a whole cohort at once.

    step(lora (C,...), frozen, opt (C,...), batch {tokens (C,B,L), labels (C,B)})
    -> (lora, opt, metrics (C,))

    Client-axis vmap over the same loss/update core as
    :func:`make_finetune_step`, so every client's update (including its own
    grad-clip global norm) is computed exactly as in the sequential path.
    With ``shared_backbone`` (the paper's setting: one pretrained W' under
    per-client LoRA deltas) the frozen tree is broadcast (``in_axes=None``)
    — XLA then fuses the cohort's backbone matmuls into single wide ops
    instead of C small ones, which is where the batched engine's speedup
    comes from.  LoRA/opt buffers are donated.
    """

    loss_fn = _finetune_loss_fn(cfg, num_classes, last_only)

    def step(lora, frozen, opt, batch):
        (loss, acc), grads = jax.value_and_grad(loss_fn, has_aux=True)(lora, frozen, batch)
        new_lora, new_opt = adamw_update(
            grads, opt, lora, lr=lr, weight_decay=weight_decay
        )
        return new_lora, new_opt, {"loss": loss, "acc": acc}

    frozen_ax = None if shared_backbone else 0
    return jax.jit(jax.vmap(step, in_axes=(0, frozen_ax, 0, 0)), donate_argnums=(0, 2))


def _distill_loss_fn(
    cfg: ModelConfig,
    temperature: float,
    lam: float,
    restrict_to_support: bool,
    last_only: bool = True,
) -> Callable:
    """loss(lora, frozen, tokens, g_logits, g_h) -> (L_total, parts)."""

    use_h = cfg.lora is not None

    def loss_fn(lora, frozen, tokens, g_logits, g_h):
        params = merge_lora(lora, frozen)
        own, aux = last_logits(params, cfg, {"tokens": tokens}, last_only=last_only)
        loss, parts = total_distill_loss(
            g_logits,
            own,
            g_h if use_h else None,
            aux.lora_h if use_h else None,
            temperature=temperature,
            lam=lam,
            restrict_to_support=restrict_to_support,
        )
        return loss + 0.01 * aux.moe_aux, parts

    return loss_fn


def _distill_step_fn(
    cfg: ModelConfig,
    lr: float,
    temperature: float,
    lam: float,
    restrict_to_support: bool,
    last_only: bool = True,
) -> Callable:
    """Unjitted single-model distillation step over merged params."""

    loss_fn = _distill_loss_fn(cfg, temperature, lam, restrict_to_support, last_only)

    def step(params, opt, tokens, g_logits, g_h):
        lora, frozen = split_lora(params)
        (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            lora, frozen, tokens, g_logits, g_h
        )
        new_lora, new_opt = adamw_update(grads, opt, lora, lr=lr)
        return merge_lora(new_lora, frozen), new_opt, {"loss": loss, **parts}

    return step


@functools.lru_cache(maxsize=64)
def make_distill_step(
    cfg: ModelConfig,
    *,
    lr: float = 1e-3,
    temperature: float = 2.0,
    lam: float = 0.03,
    restrict_to_support: bool = False,
    last_only: bool = True,
) -> Callable:
    """Knowledge-distillation update against global teacher knowledge
    (Algorithm 1 lines 5-7 / 16): LoRA-only gradient on L_total (eq. 10).

    step(params, opt, public_tokens, g_logits, g_h) -> (params, opt, metrics)
    ``g_h`` may be None -> the λ-term drops (the 'Adaptive' baseline).
    """
    return jax.jit(
        _distill_step_fn(cfg, lr, temperature, lam, restrict_to_support, last_only)
    )


@functools.lru_cache(maxsize=64)
def make_batched_distill_step(
    cfg: ModelConfig,
    *,
    lr: float = 1e-3,
    temperature: float = 2.0,
    lam: float = 0.03,
    restrict_to_support: bool = False,
    shared_backbone: bool = True,
    last_only: bool = True,
) -> Callable:
    """Cohort distillation against one broadcast teacher.

    step(lora (C,...), frozen, opt (C,...), tokens (P,L), g_logits (P,V), g_h)
    -> (lora, opt, metrics (C,))

    Teacher knowledge AND public tokens are broadcast (in_axes=None) —
    every client distills against the same {K_g, h_g}, exactly as
    Algorithm 1 lines 5-7; with ``shared_backbone`` the frozen W' is
    broadcast too (see :func:`make_batched_finetune_step`).
    """
    loss_fn = _distill_loss_fn(cfg, temperature, lam, restrict_to_support, last_only)

    def step(lora, frozen, opt, tokens, g_logits, g_h):
        (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            lora, frozen, tokens, g_logits, g_h
        )
        new_lora, new_opt = adamw_update(grads, opt, lora, lr=lr)
        return new_lora, new_opt, {"loss": loss, **parts}

    frozen_ax = None if shared_backbone else 0
    return jax.jit(
        jax.vmap(step, in_axes=(0, frozen_ax, 0, None, None, None)),
        donate_argnums=(0, 2),
    )


@functools.lru_cache(maxsize=64)
def make_batched_public_logits(
    cfg: ModelConfig, *, shared_backbone: bool = True, last_only: bool = True
) -> Callable:
    """Cohort public-set inference: (lora (C,...), frozen, tokens (P,L)) ->
    (logits (C,P,V), h (C,P,r) or None) — Algorithm 1 line 9 for the whole
    round's selected clients in one compiled call."""

    def one(lora, frozen, tokens):
        last, aux = last_logits(
            merge_lora(lora, frozen), cfg, {"tokens": tokens}, last_only=last_only
        )
        return last, aux.lora_h

    frozen_ax = None if shared_backbone else 0
    return jax.jit(jax.vmap(one, in_axes=(0, frozen_ax, None)))


@functools.lru_cache(maxsize=64)
def make_fused_round_fn(
    cfg: ModelConfig,
    num_classes: int,
    *,
    lr: float = 1e-3,
    weight_decay: float = 1e-3,
    distill_lr: float = 1e-3,
    temperature: float = 2.0,
    lam: float = 0.03,
    restrict_to_support: bool = False,
    local_steps: int = 4,
    distill_steps: int = 2,
    shared_backbone: bool = True,
    last_only: bool = True,
    use_kernels: bool = False,
) -> Callable:
    """The whole client phase of Algorithm 1 as ONE function.

    fn(lora (C,...), frozen, opt (C,...), g_tokens (P,L), g_logits (P,V),
       g_h (P,r)|None, batches {tokens (C,S,B,L), labels (C,S,B)},
       pub_tokens (P,L), ks (C,) int32)
    -> (lora, opt, dense (C,P,V), h (C,P,r)|None)

    Fuses lines 5-11 — ``distill_steps`` distillation updates against the
    broadcast knowledge, ``local_steps`` supervised updates (``lax.scan``
    over the per-step batch axis), public-set last-position inference (all
    vmapped over the client axis), and the per-client adaptive Top-k
    sparsification with the budget as DATA — so the round body is a single
    compiled program: per-round dispatches drop from
    O(distill_steps + local_steps + phases) to O(1) and no intermediate
    state round-trips through the host.  The sparsifier is the pure-jnp
    threshold bisection (:func:`repro.core.topk.topk_mask_dynamic`) or,
    with ``use_kernels``, the per-row-budget Pallas kernel
    (:func:`repro.kernels.ops.topk_mask_dynamic`) — identical threshold
    (ties-kept) semantics.  ``distill_steps=0`` builds the cold-start
    variant (round 0: no broadcast exists yet; the g_* operands are passed
    but unused and DCE'd).  Returned unjitted so the round engine chooses
    the compilation wrapper (plain ``jax.jit`` or a ``shard_map`` placement
    of the client axis over devices).
    """
    ft_loss = _finetune_loss_fn(cfg, num_classes, last_only)
    kd_loss = _distill_loss_fn(cfg, temperature, lam, restrict_to_support, last_only)

    def client_round(lora, frozen, opt, g_tokens, g_logits, g_h, batches, pub_tokens):
        # -- lines 5-7: local distillation against the broadcast knowledge --
        for _ in range(distill_steps):
            (_, _), grads = jax.value_and_grad(kd_loss, has_aux=True)(
                lora, frozen, g_tokens, g_logits, g_h
            )
            lora, opt = adamw_update(grads, opt, lora, lr=distill_lr)

        # -- line 8: local fine-tuning, scanned over the step axis --
        def train_body(carry, batch):
            lora, opt = carry
            (_, _), grads = jax.value_and_grad(ft_loss, has_aux=True)(
                lora, frozen, batch
            )
            lora, opt = adamw_update(grads, opt, lora, lr=lr, weight_decay=weight_decay)
            return (lora, opt), None

        (lora, opt), _ = jax.lax.scan(train_body, (lora, opt), batches, length=local_steps)

        # -- line 9: public last-position inference --
        last, aux = last_logits(
            merge_lora(lora, frozen), cfg, {"tokens": pub_tokens}, last_only=last_only
        )
        return lora, opt, last, aux.lora_h

    frozen_ax = None if shared_backbone else 0
    vm = jax.vmap(client_round, in_axes=(0, frozen_ax, 0, None, None, None, 0, None))

    def fn(lora, frozen, opt, g_tokens, g_logits, g_h, batches, pub_tokens, ks):
        lora, opt, last, h = vm(
            lora, frozen, opt, g_tokens, g_logits, g_h, batches, pub_tokens
        )
        # -- line 10: adaptive top-k, one budget per client row (k is data;
        # applied outside the client vmap so the Pallas path stays a plain
        # 2-D pallas_call) --
        if use_kernels:
            from repro.kernels import ops as kops

            dense = kops.topk_mask_dynamic(
                last, jnp.broadcast_to(ks[:, None], last.shape[:-1])
            )
        else:
            dense = topk_mask_dynamic(last, ks[:, None])
        return lora, opt, dense, h

    return fn


@functools.lru_cache(maxsize=64)
def make_eval_fn(
    cfg: ModelConfig, num_classes: int, *, batch_size: int = 64, last_only: bool = True
) -> Callable:
    """Accuracy over an IntentDataset (numpy arrays), batched + jitted."""

    @functools.partial(jax.jit, static_argnames=())
    def batch_acc(params, tokens, labels):
        last, _ = last_logits(params, cfg, {"tokens": tokens}, last_only=last_only)
        cls = class_logits(last, num_classes)
        return jnp.sum((jnp.argmax(cls, -1) == labels).astype(jnp.float32))

    def evaluate(params, tokens, labels) -> float:
        n = tokens.shape[0]
        correct = 0.0
        for i in range(0, n - batch_size + 1, batch_size):
            correct += float(
                batch_acc(params, tokens[i : i + batch_size], labels[i : i + batch_size])
            )
        seen = (n // batch_size) * batch_size
        return correct / max(1, seen)

    return evaluate
