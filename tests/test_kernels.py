"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.distill_kl import distill_kl_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.sparse_agg import sparse_agg_pallas
from repro.kernels.topk_select import topk_mask_pallas

# interpret=True everywhere: the kernel bodies execute under the same
# BlockSpec tiling the TPU build would use.

TOPK_SHAPES = [(1, 64), (3, 1000), (8, 4096), (5, 50288)]


@pytest.mark.parametrize("rows,vocab", TOPK_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_topk_select_sweep(rows, vocab, dtype):
    key = jax.random.PRNGKey(rows * vocab)
    x = jax.random.normal(key, (rows, vocab), jnp.float32)
    # enforce distinct values so threshold semantics == exact top-k
    x = x + jnp.arange(rows * vocab).reshape(rows, vocab) * 1e-6
    x = x.astype(dtype)
    for k in (1, 7, min(257, vocab)):
        got = topk_mask_pallas(x, k, interpret=True)
        want = ref.topk_mask_ref(x, k)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32), atol=0
        )
        if dtype == jnp.float32:
            # exact-count holds only when values are distinct; bf16
            # quantisation reintroduces ties (kept by both kernel and ref)
            assert int(jnp.sum(got != 0)) == rows * k
        else:
            assert int(jnp.sum(got != 0)) >= rows * k


def test_topk_keeps_ties():
    x = jnp.array([[1.0, 3.0, 3.0, 0.0]])
    got = topk_mask_pallas(x, 1, interpret=True)
    want = ref.topk_mask_ref(x, 1)
    np.testing.assert_allclose(got, want)
    assert int(jnp.sum(got != 0)) == 2  # both tied maxima kept


KL_SHAPES = [(1, 128), (4, 2048), (7, 5000), (16, 50288)]


@pytest.mark.parametrize("rows,vocab", KL_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("temp", [1.0, 2.0])
def test_distill_kl_sweep(rows, vocab, dtype, temp):
    key = jax.random.PRNGKey(rows + vocab)
    t = (jax.random.normal(key, (rows, vocab)) * 4).astype(dtype)
    s = (jax.random.normal(jax.random.fold_in(key, 1), (rows, vocab)) * 4).astype(dtype)
    got = distill_kl_pallas(t, s, temp, interpret=True)
    want = ref.distill_kl_ref(t, s, temp)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


AGG_SHAPES = [(2, 1, 64), (5, 8, 2048), (10, 3, 5000), (50, 2, 1024)]


@pytest.mark.parametrize("n,rows,vocab", AGG_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sparse_agg_sweep(n, rows, vocab, dtype):
    key = jax.random.PRNGKey(n * rows)
    x = jax.random.normal(key, (n, rows, vocab))
    mask = jax.random.uniform(jax.random.fold_in(key, 2), x.shape) < 0.15
    stack = jnp.where(mask, x, 0.0).astype(dtype)
    got = sparse_agg_pallas(stack, interpret=True)
    want = ref.sparse_agg_ref(stack)
    np.testing.assert_allclose(got, want, rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5,
                               atol=1e-5)


FLASH_SHAPES = [(1, 128, 64), (2, 256, 64), (3, 384, 128)]


@pytest.mark.parametrize("b,s,d", FLASH_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, s, d, dtype):
    key = jax.random.PRNGKey(b * s + d)
    q = jax.random.normal(key, (b, s, d)).astype(dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, d)).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, d)).astype(dtype)
    got = flash_attention_pallas(q, k, v, interpret=True)
    want = ref.flash_attention_ref(q, k, v)
    tol = 2e-2 if dtype == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


def test_flash_attention_is_causal():
    """Changing future keys must not change earlier outputs."""
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 256, 64))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 256, 64))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 256, 64))
    base = flash_attention_pallas(q, k, v, interpret=True)
    k2 = k.at[:, 200:].set(99.0)
    v2 = v.at[:, 200:].set(-99.0)
    pert = flash_attention_pallas(q, k2, v2, interpret=True)
    np.testing.assert_allclose(base[:, :200], pert[:, :200], rtol=1e-5, atol=1e-5)


def test_ops_wrappers_fold_batch_dims():
    from repro.kernels import ops

    x = jax.random.normal(jax.random.PRNGKey(3), (2, 3, 500))
    got = ops.topk_mask(x, 5)
    want = ref.topk_mask_ref(x.reshape(6, 500), 5).reshape(2, 3, 500)
    np.testing.assert_allclose(got, want, atol=1e-6)
