"""FL client: local LoRA fine-tuning + sparsified knowledge upload
(Algorithm 1, client loop: lines 3-12)."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.channel import ChannelState, topk_budget
from repro.core.protocol import PayloadSpec, UplinkPayload, lora_projection_bits
from repro.core.topk import SparseLogits, topk_sparsify
from repro.data.pipeline import epoch_batches
from repro.data.synthetic import IntentDataset
from repro.fed import steps as fed_steps
from repro.models import init as model_init

__all__ = ["ClientUpload", "Client", "make_upload_payload"]


def make_upload_payload(
    cfg: ModelConfig,
    client_id: int,
    num_samples: int,
    k: int,
    *,
    send_h: bool,
    value_bits: int,
    snr_db: float,
    quantize: bool = False,
) -> tuple[UplinkPayload, int | None]:
    """The single source of truth for one upload's on-air accounting
    (shared by Client.upload and the batched engine, so ledger parity can't
    drift).  Returns (payload, lora_rank or None).

    ``quantize`` prices the sparse (value, index) entries at the int8
    wire's 8 bits/value while the unquantized LoRA projection keeps
    ``value_bits`` — the split :class:`repro.core.protocol.PayloadSpec`
    models with ``h_value_bits``."""
    rank = cfg.lora.rank if (send_h and cfg.lora is not None) else None
    spec = PayloadSpec(
        num_samples=num_samples, vocab=cfg.vocab_size, k=k,
        lora_rank=rank,
        value_bits=8 if quantize else value_bits,
        h_value_bits=value_bits if quantize else None,
    )
    return UplinkPayload(client_id=client_id, spec=spec, snr_db=snr_db), rank


@dataclasses.dataclass
class ClientUpload:
    client_id: int
    sparse: SparseLogits  # top-k (values, indices) on the public set
    h: jax.Array | None  # (P, r) LoRA projections (paper eq. 8)
    payload: UplinkPayload  # byte accounting
    k: int


class Client:
    def __init__(
        self,
        client_id: int,
        cfg: ModelConfig,
        private_data: IntentDataset,
        *,
        num_classes: int,
        seed: int = 0,
        lr: float = 1e-3,
        distill_lr: float = 1e-3,
        temperature: float = 2.0,
        lam: float = 0.03,
        batch_size: int = 32,
        local_steps: int = 4,
        distill_steps: int = 2,
        restrict_to_support: bool = False,
        last_only: bool = True,
        initial_params=None,
    ):
        self.client_id = client_id
        self.cfg = cfg
        self.data = private_data
        self.num_classes = num_classes
        self.batch_size = batch_size
        self.local_steps = local_steps
        self.distill_steps = distill_steps
        self.last_only = last_only
        if initial_params is not None:
            # shared pretrained backbone W' (paper eq. 1) + fresh LoRA delta
            import jax as _jax

            from repro.lora import merge_lora, split_lora

            own_lora, _ = split_lora(model_init(_jax.random.PRNGKey(seed), cfg))
            _, frozen = split_lora(initial_params)
            self.params = merge_lora(own_lora, frozen)
        else:
            self.params = model_init(jax.random.PRNGKey(seed), cfg)
        self.opt = fed_steps.init_lora_opt(self.params, cfg)
        self._train_step = fed_steps.make_finetune_step(
            cfg, num_classes, lr=lr, last_only=last_only
        )
        self._distill_step = fed_steps.make_distill_step(
            cfg, lr=distill_lr, temperature=temperature, lam=lam,
            restrict_to_support=restrict_to_support, last_only=last_only,
        )
        self._rng = np.random.default_rng(seed + 1000 * (client_id + 1))

    def next_train_batches(self, num_steps: int) -> list[dict]:
        """Draw the next ``num_steps`` private batches, advancing this
        client's RNG stream exactly as :meth:`local_train` consumes it — the
        batched engine pulls batches through here so both engines see
        identical data under the same seed."""
        out: list[dict] = []
        while len(out) < num_steps:
            for batch in epoch_batches(self.data, self.batch_size, rng=self._rng):
                out.append(batch)
                if len(out) >= num_steps:
                    break
        return out

    # ---- Algorithm 1, line 8: local supervised fine-tuning ----
    def local_train(self) -> dict:
        metrics = {}
        for batch in self.next_train_batches(self.local_steps):
            jb = {k: jnp.asarray(v) for k, v in batch.items()}
            self.params, self.opt, metrics = self._train_step(self.params, self.opt, jb)
        return {k: float(v) for k, v in metrics.items()}

    # ---- Algorithm 1, lines 5-7: local distillation vs global knowledge ----
    def local_distill(self, public_tokens, g_logits, g_h) -> dict:
        metrics = {}
        for _ in range(self.distill_steps):
            self.params, self.opt, metrics = self._distill_step(
                self.params, self.opt, public_tokens, g_logits, g_h
            )
        return {k: float(v) for k, v in metrics.items()}

    # ---- Algorithm 1, lines 9-11: infer public set, top-k, upload ----
    def upload(
        self,
        public_tokens: jax.Array,
        channel: ChannelState,
        *,
        value_bits: int = 16,
        k_override: int | None = None,
        send_h: bool = True,
        k_min: int = 1,
    ) -> ClientUpload | None:
        """Returns None when the round's budget yields ``k == 0`` — a
        straggler in outage transmits nothing and must not be zero-padded
        into aggregation.  That happens when the budget cannot afford a
        single (value, index) entry and ``k_min == 0``, OR (deep fade under
        ``send_h``) when the reserved projection bits alone exceed the
        Shannon budget: :func:`repro.core.channel.topk_budget` drops such a
        round entirely rather than emitting a ``k_min``-floored payload
        that cannot fit the link.

        With ``send_h`` the LoRA-projection bits ride on the same Shannon
        budget, so they are reserved out of it before the top-k entries are
        counted — the realized payload (projection included) then satisfies
        :meth:`repro.core.protocol.PayloadSpec.fits` by construction."""
        vocab = self.cfg.vocab_size
        n_samples = int(public_tokens.shape[0])
        if k_override is not None:
            k = int(min(k_override, vocab))
        else:
            reserved = (
                lora_projection_bits(n_samples, self.cfg.lora.rank, value_bits)
                if (send_h and self.cfg.lora is not None)
                else 0
            )
            k = topk_budget(
                channel, vocab_size=vocab, num_samples=n_samples,
                value_bits=value_bits, k_min=k_min, reserved_bits=reserved,
            )
        if k == 0:
            return None
        logits, h = fed_steps.public_logits(
            self.params, self.cfg, public_tokens, last_only=self.last_only
        )
        sparse = topk_sparsify(logits, k)
        payload, _ = make_upload_payload(
            self.cfg, self.client_id, n_samples, k,
            send_h=send_h, value_bits=value_bits, snr_db=channel.snr_db,
        )
        return ClientUpload(
            client_id=self.client_id,
            sparse=sparse,
            h=h if send_h else None,
            payload=payload,
            k=k,
        )
