"""llama4-scout-17b-a16e — MoE decoder, 16 experts top-1, early fusion.

[hf:meta-llama/Llama-4-Scout-17B-16E] 48 layers, d_model=5120, 40 q heads /
8 kv heads (GQA), per-expert d_ff=8192, vocab 202048, 16 experts top-1
routing (17B active of 109B total).  Every layer MoE here (the release
interleaves a shared expert; the routed-expert path is what stresses the
framework's expert-parallel sharding).  bf16 params + remat to fit v5e HBM.
"""

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=202_048,
    moe=MoEConfig(num_experts=16, top_k=1, d_ff=8192, capacity_factor=1.25),
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    optimizer_state_dtype="bfloat16",
    remat=True,
    microbatches=16,
    max_seq_len=262_144,
    cite="hf:meta-llama/Llama-4-Scout-17B-16E",
)

SMOKE_CONFIG = CONFIG.with_overrides(
    name="llama4-smoke", num_layers=2, d_model=256, num_heads=8, num_kv_heads=2,
    d_ff=256, vocab_size=512, moe=MoEConfig(num_experts=4, top_k=1, d_ff=256),
    param_dtype="float32", compute_dtype="float32", optimizer_state_dtype="float32",
    remat=False, max_seq_len=256,
)
