"""Adaptive Top-k logit sparsification (paper §III-A, eqs. 3-4).

Each client keeps only the k largest logits per sample:

    K̃_{n,c}(x) = K_{n,c}(x) * 1[c in I_{n,k}(x)]        (eq. 4)

Two representations are used throughout the framework:

* **sparse** ``(values, indices)`` of shape ``(..., k)`` — what is actually
  "transmitted" (its size is exactly the paper's ``k * d`` bits);
* **dense** ``(..., vocab)`` with zeros off-support — what aggregation
  consumes (paper's server-side view).

Dense top-k masking for very large vocabularies (50k-256k in the assigned
architectures) is the compute hot-spot of the uplink path; a Pallas
bisection-select kernel (:mod:`repro.kernels.topk_select`) implements it
TPU-natively.  This module is the pure-jnp composable API; ``use_kernel=True``
routes to the kernel.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = [
    "SparseLogits",
    "topk_sparsify",
    "topk_mask_dense",
    "densify",
    "sparsify_batch",
    "payload_entries",
]


class SparseLogits(NamedTuple):
    """Transmitted sparse representation of one client's logits.

    values:  (..., k) top-k logit values, descending.
    indices: (..., k) vocab indices of those values (int32).
    k:       static python int — the channel-adaptive budget this round.
    vocab:   static python int — full dimensionality c.
    """

    values: jax.Array
    indices: jax.Array
    k: int
    vocab: int


def topk_sparsify(logits: jax.Array, k: int) -> SparseLogits:
    """Select the top-k logits per row (paper eq. 3).

    Works for any leading batch shape; the last axis is the vocab axis.
    """
    vocab = logits.shape[-1]
    k = int(min(k, vocab))
    values, indices = jax.lax.top_k(logits, k)
    return SparseLogits(values=values, indices=indices.astype(jnp.int32), k=k, vocab=vocab)


def densify(sparse: SparseLogits, *, fill: float = 0.0) -> jax.Array:
    """Scatter a sparse payload back to a dense ``(..., vocab)`` vector
    (paper eq. 4: zeros off the top-k support, unless ``fill`` overrides)."""
    batch_shape = sparse.values.shape[:-1]
    dense = jnp.full(batch_shape + (sparse.vocab,), fill, dtype=sparse.values.dtype)
    return _scatter_last(dense, sparse.indices, sparse.values)


def _scatter_last(dense: jax.Array, indices: jax.Array, values: jax.Array) -> jax.Array:
    """Scatter ``values`` into ``dense`` along the last axis at ``indices``."""
    # Flatten batch dims, vmap a 1-D scatter, restore shape.
    batch_shape = dense.shape[:-1]
    vocab = dense.shape[-1]
    flat_dense = dense.reshape((-1, vocab))
    flat_idx = indices.reshape((-1, indices.shape[-1]))
    flat_val = values.reshape((-1, values.shape[-1]))

    def scatter_row(row, idx, val):
        return row.at[idx].set(val)

    out = jax.vmap(scatter_row)(flat_dense, flat_idx, flat_val)
    return out.reshape(batch_shape + (vocab,))


def topk_mask_dense(logits: jax.Array, k: int, *, use_kernel: bool = False) -> jax.Array:
    """Dense top-k sparsification: keep top-k per row, zero elsewhere.

    Equivalent to ``densify(topk_sparsify(x, k))`` but computed without
    materialising indices when the Pallas kernel path is used.
    """
    if use_kernel:
        from repro.kernels import ops as kops

        return kops.topk_mask(logits, k)
    sparse = topk_sparsify(logits, k)
    return densify(sparse)


def sparsify_batch(logits: jax.Array, k: int) -> SparseLogits:
    """Alias of :func:`topk_sparsify` for (num_samples, vocab) batches —
    the per-round public-set upload of one client."""
    return topk_sparsify(logits, k)


def payload_entries(sparse: SparseLogits) -> int:
    """Number of (value, index) entries in a payload = samples * k."""
    n = 1
    for s in sparse.values.shape:
        n *= int(s)
    return n
