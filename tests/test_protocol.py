"""Communication accounting (paper §III-C, Fig. 3)."""

import pytest

from repro.core.channel import ChannelState
from repro.core.protocol import (
    CommLedger,
    PayloadSpec,
    RoundStats,
    UplinkPayload,
    full_logits_bits,
    lora_projection_bits,
    topk_upload_bits,
)


def test_topk_vs_full_savings():
    """Top-k with k << V is far cheaper than full logits; the paper's ~50%
    claim combines top-k + fewer rounds."""
    v, n = 50_288, 2000
    full = full_logits_bits(n, v)
    topk = topk_upload_bits(n, 100, v)
    assert topk < full / 100


def test_lora_projection_is_cheap():
    # r=8 projection << even a k=100 top-k payload (paper §III-C)
    assert lora_projection_bits(2000, 8) < topk_upload_bits(2000, 100, 50_288) / 10


def test_payload_spec_bits():
    spec = PayloadSpec(num_samples=10, vocab=65_536, k=5, lora_rank=8)
    # d = 16 + 16 index bits; + 8*16 bits of h per sample
    assert spec.uplink_bits == 10 * 5 * 32 + 10 * 8 * 16
    assert spec.uplink_bytes == spec.uplink_bits / 8


def test_fits_budget_invariant():
    st = ChannelState(bandwidth_hz=1e6, snr_db=0.0, eta=1.0, deadline_s=1.0)
    ok = PayloadSpec(num_samples=100, vocab=1024, k=10)  # 100*10*26 = 26k bits
    too_big = PayloadSpec(num_samples=100_000, vocab=1024, k=1000)
    assert ok.fits(st)
    assert not too_big.fits(st)


def test_ledger_threshold_metric():
    led = CommLedger()
    for i, acc in enumerate([0.2, 0.5, 0.72, 0.8]):
        led.record(RoundStats(round_index=i, uplink_bytes=1e6, downlink_bytes=1e6,
                              server_accuracy=acc))
    assert led.mb_to_reach(0.7) == pytest.approx(6.0)  # 3 rounds x 2 MB
    assert led.mb_to_reach(0.95) is None
    assert led.total_mb == pytest.approx(8.0)


def test_uplink_payload_bytes():
    spec = PayloadSpec(num_samples=4, vocab=256, k=2, lora_rank=None)
    up = UplinkPayload(client_id=0, spec=spec)
    assert up.bytes == spec.uplink_bytes


# ---- PR 6: value-bits split (quantized wire) + export surface --------------


def test_payload_spec_h_value_bits_split():
    """A quantized payload prices its (value, index) entries at 8 bits while
    the unquantized LoRA projection keeps its own width (h_value_bits)."""
    q = PayloadSpec(
        num_samples=10, vocab=65_536, k=5, lora_rank=8,
        value_bits=8, h_value_bits=16,
    )
    # d = 8 + 16 index bits; + 8*16 bits of h per sample
    assert q.uplink_bits == 10 * 5 * 24 + 10 * 8 * 16
    # h_value_bits=None falls back to value_bits for the projection
    f = PayloadSpec(num_samples=10, vocab=65_536, k=5, lora_rank=8, value_bits=8)
    assert f.uplink_bits == 10 * 5 * 24 + 10 * 8 * 8
    # at equal k the quantized spec is strictly cheaper than the float one
    base = PayloadSpec(num_samples=10, vocab=65_536, k=5, lora_rank=8)
    assert q.uplink_bits < base.uplink_bits


def test_make_upload_payload_quantize_pricing():
    """The engines' single accounting source prices quantized uploads at
    8-bit entries + value_bits projection."""
    from repro.configs import get_smoke_config

    from repro.fed.client import make_upload_payload

    cfg = get_smoke_config("gpt2-paper")
    q, rank = make_upload_payload(
        cfg, 0, 10, 5, send_h=True, value_bits=16, snr_db=0.0, quantize=True
    )
    f, _ = make_upload_payload(
        cfg, 0, 10, 5, send_h=True, value_bits=16, snr_db=0.0
    )
    assert rank == cfg.lora.rank
    assert q.spec.value_bits == 8 and q.spec.h_value_bits == 16
    assert f.spec.value_bits == 16 and f.spec.h_value_bits is None
    # equal-shape savings: same k, same h, strictly fewer bits on the wire
    assert q.spec.uplink_bits < f.spec.uplink_bits
    # the projection is priced identically in both, so the whole difference
    # is the 8 bits shaved off each of the 10*5 (value, index) entries
    assert f.spec.uplink_bits - q.spec.uplink_bits == 10 * 5 * 8
    from repro.core.channel import bits_per_entry

    h_bits = lora_projection_bits(10, cfg.lora.rank, 16)
    assert q.spec.uplink_bits == 10 * 5 * bits_per_entry(8, cfg.vocab_size) + h_bits


def test_protocol_exports_downlink_and_round_totals():
    """PR-6 export fix: downlink_bits/total_round_bytes are public API (the
    engines and ledger plots import them)."""
    import repro.core.protocol as proto

    assert "downlink_bits" in proto.__all__
    assert "total_round_bytes" in proto.__all__
    assert callable(proto.downlink_bits)
    assert callable(proto.total_round_bytes)
