"""Data pipeline: synthetic Banking77 statistics, Dirichlet partition."""

import numpy as np

from repro.data import (
    batch_iterator,
    dirichlet_partition,
    iid_partition,
    make_banking77_like,
    make_lm_stream,
    split_public_private,
)


def test_banking77_statistics():
    ds = make_banking77_like(seed=0)
    assert len(ds) == 13_083  # paper Table I: total inquiries
    assert ds.num_classes == 77  # intent categories
    assert ds.tokens.dtype == np.int32
    assert ds.tokens.min() >= 0 and ds.tokens.max() < ds.vocab_size
    # every class present
    assert len(np.unique(ds.labels)) == 77


def test_banking77_learnable_structure():
    """Keyword injection must create class-token mutual information: a naive
    keyword-matching classifier beats chance by a wide margin."""
    ds = make_banking77_like(vocab_size=512, seq_len=24, total=4000, seed=1)
    # top tokens per class from train half, score test half
    half = len(ds) // 2
    counts = np.zeros((77, 512))
    for t, l in zip(ds.tokens[:half], ds.labels[:half]):
        np.add.at(counts[l], t, 1)
    prior = counts.sum(0) + 1
    scores = np.log(counts + 1) - np.log(prior)
    correct = 0
    for t, l in zip(ds.tokens[half:], ds.labels[half:]):
        pred = np.argmax(scores[:, t].sum(axis=1))
        correct += pred == l
    acc = correct / (len(ds) - half)
    assert acc > 0.5, f"synthetic task not learnable: {acc:.3f}"


def test_dirichlet_partition_covers_everything():
    ds = make_banking77_like(total=2000, seed=2)
    parts = dirichlet_partition(ds.labels, 20, gamma=0.5, seed=0)
    all_idx = np.sort(np.concatenate(parts))
    assert len(all_idx) == len(ds)
    assert len(np.unique(all_idx)) == len(ds)  # disjoint cover
    assert all(len(p) >= 2 for p in parts)


def test_dirichlet_is_non_iid():
    """γ=0.5 must produce skewed class distributions vs IID."""
    ds = make_banking77_like(total=4000, seed=3)
    parts = dirichlet_partition(ds.labels, 10, gamma=0.5, seed=0)
    iid = iid_partition(len(ds), 10, seed=0)

    def skew(parts):
        out = []
        for p in parts:
            hist = np.bincount(ds.labels[p], minlength=77) / max(1, len(p))
            out.append(np.max(hist))
        return np.mean(out)

    assert skew(parts) > 1.5 * skew(iid)


def test_public_private_split():
    ds = make_banking77_like(total=3000, seed=4)
    pub, priv = split_public_private(ds, 500, seed=0)
    assert len(pub) == 500 and len(priv) == 2500


def test_batch_iterator_shapes_and_cap():
    ds = make_banking77_like(total=300, seed=5)
    batches = list(batch_iterator(ds, 32, seed=0, max_batches=7))
    assert len(batches) == 7
    for b in batches:
        assert b["tokens"].shape == (32, ds.seq_len)
        assert b["labels"].shape == (32,)


def test_lm_stream():
    x = make_lm_stream(vocab_size=1000, seq_len=64, num_samples=10, seed=0)
    assert x.shape == (10, 64) and x.dtype == np.int32
    assert x.min() >= 0 and x.max() < 1000
    # bigram structure: repeated-successor rate beats uniform chance
    x2 = make_lm_stream(vocab_size=1000, seq_len=64, num_samples=10, seed=0)
    np.testing.assert_array_equal(x, x2)  # deterministic
