"""moonshot-v1-16b-a3b — fine-grained MoE (64 experts, top-6).

[hf:moonshotai/Moonlight-16B-A3B] 48 layers, d_model=2048, 16 heads
(kv=16, MHA), per-expert d_ff=1408 (DeepSeek-V3-style fine-grained experts),
vocab 163840, 64 experts top-6 (~3B active of 16B).  The release keeps the
first layer dense and adds shared experts; here every layer is routed MoE —
the uniform-scan form that stresses expert-parallel all-to-all hardest
(noted adaptation, DESIGN §5).
"""

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=163_840,
    moe=MoEConfig(num_experts=64, top_k=6, d_ff=1408, capacity_factor=1.25),
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat=True,
    microbatches=4,
    max_seq_len=131_072,
    cite="hf:moonshotai/Moonlight-16B-A3B",
)

SMOKE_CONFIG = CONFIG.with_overrides(
    name="moonshot-smoke", num_layers=2, d_model=256, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=512, moe=MoEConfig(num_experts=4, top_k=2, d_ff=128),
    param_dtype="float32", compute_dtype="float32", remat=False, max_seq_len=256,
)
