"""Production mesh construction (MULTI-POD DRY-RUN spec, step 1).

A FUNCTION, not a module-level constant: importing this module never touches
jax device state.  TPU v5e hardware constants used by the roofline live here
too so benchmarks and launch agree on them.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh", "make_client_mesh", "V5E"]

# TPU v5e per-chip constants (roofline denominators).
V5E = {
    "peak_bf16_flops": 197e12,  # FLOP/s
    "hbm_bandwidth": 819e9,  # B/s
    "ici_link_bandwidth": 50e9,  # B/s per link
    "hbm_bytes": 16 * 1024**3,
}


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; multi_pod adds the 2-pod leading axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_host_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh over however many (host) devices tests were launched with."""
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_client_mesh():
    """The federated engines' cohort placement: a 1-D mesh over all devices
    (axis ``"clients"``).  Launch-side alias of
    :func:`repro.sharding.cohort_mesh` so FL drivers and the production
    launcher construct meshes from one module."""
    from repro.sharding import cohort_mesh

    return cohort_mesh()
