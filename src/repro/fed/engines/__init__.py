"""Client-phase execution engines for the federated round loop.

The paper's Algorithm 1 runs the selected cohort's client work (local
distillation, local fine-tuning, public-set inference + adaptive Top-k
upload) independently per client — embarrassingly parallel across the
cohort.  Interchangeable engines execute that phase:

* :class:`SequentialEngine` — the reference implementation: a Python loop
  over clients, one jitted step per client (the seed repo's behaviour).
* :class:`BatchedEngine` — keeps the fleet's LoRA/optimizer state in a
  :class:`repro.fed.store.FleetStore` and runs every phase as a single
  ``jax.vmap``-ed, ``jax.jit``-compiled, donated-buffer step over a leading
  client axis: host dispatches per round drop from O(C·steps) to O(steps),
  and the client axis is the handle accelerator backends parallelise over.
* :class:`FusedEngine` — collapses the batched engine's per-phase calls
  into ONE donated, jitted round body; the client axis can optionally be
  placed over devices with ``jax.experimental.shard_map``
  (``shard_clients=True``).
* :class:`FusedE2EEngine` — the whole round (client AND server phase) as
  one compiled call, sparse wire across the boundary, plus the
  multi-round ``lax.scan`` driver.
* :class:`HeteroClientEngine` / :class:`HeteroFusedE2EEngine` — the
  family-bucketed versions of the above for heterogeneous fleets.

All engines are driven by :func:`repro.fed.rounds.run_federated`.
Sequential and batched are bit-compatible under the same seed; the fused
engines are tolerance-compatible: identical per-client adaptive ``k`` and
ledger bytes (the budget math is the same host-side scalar code), while
accuracies/logits may drift by float round-off.  Batches are drawn through
the same per-client RNG streams in every engine.

Fleet-state residency is the engines' ``fleet_store`` knob (PR 9): the
default ``"device"`` store keeps the fleet stacked on-device exactly as
before the refactor; ``"host"`` keeps the fleet in host memory (optionally
npz-spilled) and streams only each round's cohort to the device, with a
prefetch hook overlapping the next cohort's transfer with the current
round's compute — see :mod:`repro.fed.store`.

Straggler semantics (all engines): a client whose channel state yields
``k == 0`` transmits nothing — it contributes zero uplink bytes and is
excluded from the aggregation stack entirely rather than zero-padded in.
"""

from __future__ import annotations

from repro.configs.base import ModelConfig
from repro.fed.client import Client
from repro.fed.engines.base import (
    BroadcastState,
    ClientPhase,
    RoundsTrajectory,
    SequentialEngine,
    _channel_scan_ops,
    _ServerOwnerMixin,
    check_unique_cohort,
    cohort_budgets,
    fake_quant_dense,
    k_cap_bucket,
    shared_frozen_backbone,
    tree_stack,
)
from repro.fed.engines.batched import BatchedEngine
from repro.fed.engines.e2e import FusedE2EEngine
from repro.fed.engines.fused import FusedEngine
from repro.fed.engines.hetero import HeteroClientEngine, HeteroFusedE2EEngine

__all__ = [
    "BroadcastState",
    "ClientPhase",
    "RoundsTrajectory",
    "SequentialEngine",
    "BatchedEngine",
    "FusedEngine",
    "FusedE2EEngine",
    "HeteroClientEngine",
    "HeteroFusedE2EEngine",
    "make_engine",
    "tree_stack",
    "k_cap_bucket",
    "cohort_budgets",
    "check_unique_cohort",
]

# referenced via the package for the engine.py shim's star-import era callers
_PRIVATE_REEXPORTS = (_ServerOwnerMixin, _channel_scan_ops, fake_quant_dense,
                      shared_frozen_backbone)


def make_engine(kind: str, clients: list[Client], cfg: ModelConfig, **kwargs):
    """Build a round engine.  A fleet whose clients run more than one
    :class:`ModelConfig` (``client.cfg`` differs) is served by the
    family-bucketed heterogeneous engines for every fast ``kind`` — same
    interface, per-bucket executables — while ``sequential`` handles mixed
    fleets natively (each client runs its own architecture)."""
    if kind != "fused_e2e":
        for e2e_only in ("server", "server_distill_steps", "aggregation"):
            kwargs.pop(e2e_only, None)
    if kind == "sequential":
        if kwargs.get("quantize_wire"):
            raise NotImplementedError(
                "quantize_wire is not supported by the sequential reference"
                " engine — use 'batched', 'fused' or 'fused_e2e'"
            )
        if kwargs.get("compute_dtype", "float32") != "float32":
            raise NotImplementedError(
                "compute_dtype is not supported by the sequential reference"
                " engine — use 'fused' or 'fused_e2e'"
            )
        store = kwargs.get("fleet_store", "device")
        if store != "device" and getattr(store, "kind", store) != "device":
            raise NotImplementedError(
                "fleet_store='host' is not supported by the sequential"
                " reference engine (it keeps per-client state inside the"
                " Client objects) — use 'batched', 'fused' or 'fused_e2e'"
            )
        return SequentialEngine(
            clients, cfg,
            value_bits=kwargs.get("value_bits", 16), k_min=kwargs.get("k_min", 1),
        )
    hetero = len({c.cfg for c in clients}) > 1
    if kind == "batched":
        kwargs.pop("shard_clients", None)
        kwargs.pop("use_kernels", None)
        # the batched engine is the fp32 per-phase reference; the bf16 round
        # body exists only on the fused single-executable paths
        kwargs.pop("compute_dtype", None)
        if hetero:
            return HeteroClientEngine(kind, clients, **kwargs)
        return BatchedEngine(clients, cfg, **kwargs)
    if kind == "fused":
        if hetero:
            return HeteroClientEngine(kind, clients, **kwargs)
        return FusedEngine(clients, cfg, **kwargs)
    if kind == "fused_e2e":
        if hetero:
            return HeteroFusedE2EEngine(clients, **kwargs)
        return FusedE2EEngine(clients, cfg, **kwargs)
    raise ValueError(
        f"unknown engine: {kind!r} (expected 'sequential', 'batched', 'fused'"
        " or 'fused_e2e')"
    )
