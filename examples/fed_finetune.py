"""End-to-end driver (deliverable b): federated fine-tuning of a ~language
model family for a few hundred steps, reproducing the paper's experiment
shape — 4 methods, accuracy + exact communication accounting.

Each round runs clients_per_round x (local_steps + distill_steps) model
updates plus server distillation; 12 rounds x 4 clients x 8 steps ≈ 400+
optimisation steps end-to-end.

Run:  PYTHONPATH=src python examples/fed_finetune.py [rounds] [engine]

``engine`` is ``batched`` (default: the whole selected cohort advances as
single vmapped/jitted per-phase steps), ``fused`` (the entire client phase
— distill, fine-tune, public inference, adaptive top-k — as ONE donated
jitted call per round), ``fused_e2e`` (the WHOLE round — client phase plus
sparse-wire aggregation, server distillation and broadcast — as one
compiled call) or ``sequential`` (the bit-compatible one-client-at-a-time
reference) — see FedConfig.engine.  All engines use the
last-position-only LM head (FedConfig.last_only).
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.gpt2_paper import REDUCED_CLIENT, REDUCED_SERVER  # noqa: E402
from repro.data import make_banking77_like  # noqa: E402
from repro.fed import FedConfig, run_federated  # noqa: E402

rounds = int(sys.argv[1]) if len(sys.argv) > 1 else 12
engine = sys.argv[2] if len(sys.argv) > 2 else "batched"

client_cfg = REDUCED_CLIENT
server_cfg = REDUCED_SERVER
dataset = make_banking77_like(vocab_size=client_cfg.vocab_size, seq_len=24, seed=0)

print(f"clients: {client_cfg.name} ({client_cfg.param_count()/1e6:.1f}M params)  "
      f"server: {server_cfg.name} ({server_cfg.param_count()/1e6:.1f}M params)  "
      f"engine: {engine}")

results = {}
for method in ("adald", "zeropad"):
    fed = FedConfig(
        method=method, engine=engine, num_clients=10, clients_per_round=4, rounds=rounds,
        public_size=512, public_batch=96, eval_size=512,
        local_steps=6, distill_steps=2, seed=0,
    )
    print(f"\n=== {method} ===")
    run = run_federated(client_cfg, server_cfg, dataset, fed, verbose=True)
    results[method] = run
    print(f"{method}: best server acc {max(run.server_acc):.3f}, "
          f"uplink {run.ledger.uplink_mb:.2f} MB")

a, z = results["adald"], results["zeropad"]
print("\n=== comparison (paper Fig. 2 ordering) ===")
print(f"AdaLD   best={max(a.server_acc):.3f}  uplink={a.ledger.uplink_mb:.2f} MB")
print(f"ZeroPad best={max(z.server_acc):.3f}  uplink={z.ledger.uplink_mb:.2f} MB")
