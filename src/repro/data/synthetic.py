"""Synthetic datasets matching the paper's experimental statistics.

Banking77 [arXiv:2003.04807] is an intent-classification set: 13,083 online
banking queries over 77 intents.  The real corpus is not available offline,
so we synthesise a *statistics-matched* stand-in: 77 classes, 13,083
samples, short token sequences whose distribution is class-conditional (each
class owns a token-frequency profile plus a few "keyword" tokens), making the
task genuinely learnable — models must pick up class-token correlations, and
harder class pairs share keywords (non-trivial decision boundaries).

Classification head convention (GPT-2 style, as the paper fine-tunes
decoder-only LMs for intent detection): class logits are read from the
LM head restricted to the first 77 vocab ids at the last position.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["IntentDataset", "make_banking77_like", "make_fed_benchmark_dataset", "make_lm_stream"]

BANKING77_NUM_CLASSES = 77
BANKING77_TOTAL = 13_083


@dataclasses.dataclass
class IntentDataset:
    tokens: np.ndarray  # (N, S) int32
    labels: np.ndarray  # (N,) int32
    num_classes: int
    vocab_size: int
    seq_len: int

    def __len__(self) -> int:
        return self.tokens.shape[0]

    def subset(self, idx: np.ndarray) -> "IntentDataset":
        return IntentDataset(
            tokens=self.tokens[idx],
            labels=self.labels[idx],
            num_classes=self.num_classes,
            vocab_size=self.vocab_size,
            seq_len=self.seq_len,
        )


def make_banking77_like(
    *,
    vocab_size: int = 1024,
    seq_len: int = 32,
    num_classes: int = BANKING77_NUM_CLASSES,
    total: int = BANKING77_TOTAL,
    keyword_strength: float = 0.35,
    shared_frac: float = 0.3,
    seed: int = 0,
) -> IntentDataset:
    """Class-conditional token sequences.

    Each class c gets 4 keyword tokens; with prob ``keyword_strength`` a
    position emits one of them, else a draw from a class-tilted background
    distribution.  ``shared_frac`` of classes share one keyword with a
    neighbour class (confusable intents, as in real Banking77).
    """
    rng = np.random.default_rng(seed)
    # Reserve ids [0, num_classes) for the label-token readout convention.
    lo = num_classes
    keywords = rng.integers(lo, vocab_size, size=(num_classes, 4))
    for c in range(int(num_classes * shared_frac)):
        keywords[c, 3] = keywords[(c + 1) % num_classes, 0]  # confusable pair

    # class-tilted background: Dirichlet token profile per class
    base = rng.dirichlet(np.full(vocab_size - lo, 0.1), size=num_classes)

    labels = rng.integers(0, num_classes, size=total).astype(np.int32)
    tokens = np.empty((total, seq_len), np.int32)
    for c in range(num_classes):
        idx = np.where(labels == c)[0]
        if idx.size == 0:
            continue
        n = idx.size * seq_len
        bg = rng.choice(vocab_size - lo, size=n, p=base[c]) + lo
        kw = keywords[c][rng.integers(0, 4, size=n)]
        use_kw = rng.random(n) < keyword_strength
        seq = np.where(use_kw, kw, bg).reshape(idx.size, seq_len).astype(np.int32)
        tokens[idx] = seq
    return IntentDataset(
        tokens=tokens,
        labels=labels,
        num_classes=num_classes,
        vocab_size=vocab_size,
        seq_len=seq_len,
    )


def make_lm_stream(
    *, vocab_size: int, seq_len: int, num_samples: int, seed: int = 0
) -> np.ndarray:
    """Synthetic LM token stream with mild bigram structure, (N, S) int32.

    Used for training-throughput benchmarks and the public distillation set
    when no labels are needed.
    """
    rng = np.random.default_rng(seed)
    # sparse bigram transition: each token prefers a small successor set
    succ = rng.integers(0, vocab_size, size=(min(vocab_size, 4096), 8))
    out = np.empty((num_samples, seq_len), np.int64)
    cur = rng.integers(0, vocab_size, size=num_samples)
    for t in range(seq_len):
        out[:, t] = cur
        stay = rng.random(num_samples) < 0.7
        nxt_pref = succ[cur % succ.shape[0], rng.integers(0, 8, size=num_samples)]
        nxt_rand = rng.integers(0, vocab_size, size=num_samples)
        cur = np.where(stay, nxt_pref, nxt_rand)
    return out.astype(np.int32)


def make_fed_benchmark_dataset(vocab_size: int, *, seed: int = 0, total: int = 2500) -> IntentDataset:
    """The tuned-hardness dataset used by the FL benchmarks/tests: weak
    keywords + many confusable intents, so (i) the 80-step client pretrain
    lands at moderate accuracy (~0.4) and (ii) distillation rounds have
    headroom to demonstrate transfer (DESIGN §1 calibration)."""
    return make_banking77_like(
        vocab_size=vocab_size, seq_len=20, total=total,
        keyword_strength=0.08, shared_frac=0.7, seed=seed,
    )
