"""Optimizer + schedules."""

import jax
import jax.numpy as jnp
import pytest

from repro.optim import adamw_init, adamw_update, constant, global_norm, warmup_cosine, warmup_linear


def test_adamw_minimises_quadratic():
    params = {"w": jnp.array([5.0, -3.0, 2.0])}
    opt = adamw_init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    for _ in range(200):
        grads = jax.grad(loss)(params)
        params, opt = adamw_update(grads, opt, params, lr=5e-2, weight_decay=0.0)
    assert float(loss(params)) < 1e-2


def test_adamw_bf16_state_roundtrip():
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    opt = adamw_init(params, state_dtype="bfloat16")
    assert opt.m["w"].dtype == jnp.bfloat16
    grads = {"w": jnp.ones((4,), jnp.bfloat16)}
    new_p, new_opt = adamw_update(grads, opt, params, lr=1e-2)
    assert new_p["w"].dtype == jnp.bfloat16
    assert int(new_opt.count) == 1
    assert bool(jnp.all(new_p["w"] < params["w"]))


def test_grad_clipping():
    params = {"w": jnp.zeros((3,))}
    opt = adamw_init(params)
    huge = {"w": jnp.full((3,), 1e9)}
    new_p, _ = adamw_update(huge, opt, params, lr=1.0, grad_clip=1.0)
    assert bool(jnp.all(jnp.isfinite(new_p["w"])))
    assert float(jnp.max(jnp.abs(new_p["w"]))) <= 1.5  # one adam step, clipped


def test_global_norm():
    t = {"a": jnp.array([3.0]), "b": jnp.array([4.0])}
    assert float(global_norm(t)) == pytest.approx(5.0)


def test_schedules():
    sc = warmup_cosine(1.0, 10, 100)
    assert float(sc(0)) == 0.0
    assert float(sc(10)) == pytest.approx(1.0, rel=1e-3)
    assert float(sc(100)) == pytest.approx(0.1, rel=1e-2)  # final_frac
    lin = warmup_linear(2.0, 5, 50)
    assert float(lin(5)) == pytest.approx(2.0)
    assert float(lin(50)) == pytest.approx(0.0, abs=1e-6)
    assert float(constant(0.3)(123)) == pytest.approx(0.3)
