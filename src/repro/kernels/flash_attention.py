"""Pallas TPU kernel: blockwise causal flash attention (prefill hot-spot).

Standard flash-attention-2 schedule adapted to the TPU grid model:
grid = (batch*heads, q_blocks, kv_blocks) with the kv axis innermost and
sequential; scratch carries the running max m, normaliser l and output
accumulator per q block.  Causality is enforced at two granularities:
whole kv-tiles strictly above the diagonal are skipped via ``pl.when``
(no FLOPs, no HBM reads scheduled into the MXU), and the diagonal tile uses
an element mask.  Block sizes default to 128x128 — MXU-aligned.

Used by the prefill path where S is large (32k); the backward pass uses the
jnp reference (prefill is inference-only in this framework).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_pallas"]

Q_BLK = 128
KV_BLK = 128
_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *, scale: float, n_kv: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr[...], _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr[...])
        acc_scr[...] = jnp.zeros_like(acc_scr[...])

    @pl.when(kj <= qi)  # skip fully-masked tiles above the causal diagonal
    def _compute():
        q = q_ref[0].astype(jnp.float32)  # (Qb, D)
        k = k_ref[0].astype(jnp.float32)  # (Kb, D)
        v = v_ref[0].astype(jnp.float32)
        scores = (q @ k.T) * scale  # (Qb, Kb)

        @pl.when(kj == qi)
        def _mask_diag():
            rows = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 0)
            cols = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
            scores_m = jnp.where(rows >= cols, scores, _NEG_INF)
            _online_update(scores_m, v, m_scr, l_scr, acc_scr)

        @pl.when(kj < qi)
        def _full_tile():
            _online_update(scores, v, m_scr, l_scr, acc_scr)

    @pl.when(kj == n_kv - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] / l_scr[...][:, None]).astype(o_ref.dtype)


def _online_update(scores, v, m_scr, l_scr, acc_scr):
    m_old = m_scr[...]
    m_new = jnp.maximum(m_old, jnp.max(scores, axis=-1))
    p = jnp.exp(scores - m_new[:, None])
    rescale = jnp.exp(m_old - m_new)
    l_scr[...] = l_scr[...] * rescale + jnp.sum(p, axis=-1)
    acc_scr[...] = acc_scr[...] * rescale[:, None] + p @ v
    m_scr[...] = m_new


@functools.partial(jax.jit, static_argnames=("interpret",))
def flash_attention_pallas(
    q: jax.Array, k: jax.Array, v: jax.Array, *, interpret: bool = False
) -> jax.Array:
    """Causal attention over (B, S, D) fused head-batches."""
    b, s, d = q.shape
    qb = min(Q_BLK, s)
    kb = min(KV_BLK, s)
    assert s % qb == 0 and s % kb == 0, f"seq {s} must tile by {qb}/{kb}"
    scale = d**-0.5
    n_kv = s // kb
    grid = (b, s // qb, n_kv)

    return pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, n_kv=n_kv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, qb, d), lambda b_, i, j: (b_, i, 0)),
            pl.BlockSpec((1, kb, d), lambda b_, i, j: (b_, j, 0)),
            pl.BlockSpec((1, kb, d), lambda b_, i, j: (b_, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, qb, d), lambda b_, i, j: (b_, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((qb,), jnp.float32),
            pltpu.VMEM((qb,), jnp.float32),
            pltpu.VMEM((qb, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
