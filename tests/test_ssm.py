"""SSD core: chunked algorithm vs naive recurrent oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.ssm import _segsum, _ssd_chunked

pytestmark = pytest.mark.slow  # model-zoo/layer suites ride the slow tier


def naive_ssd(x, a, b_mat, c_mat, init_state=None):
    """Direct recurrence: state_t = exp(a_t)*state_{t-1} + B_t (x) x_t."""
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    state = jnp.zeros((bsz, h, p, n)) if init_state is None else init_state
    ys = []
    for t in range(s):
        decay = jnp.exp(a[:, t])  # (B,H)
        state = state * decay[..., None, None] + jnp.einsum(
            "bhp,bn->bhpn", x[:, t], b_mat[:, t]
        )
        ys.append(jnp.einsum("bhpn,bn->bhp", state, c_mat[:, t]))
    return jnp.stack(ys, axis=1), state


@pytest.mark.parametrize("chunk", [1, 2, 4, 8])
@pytest.mark.parametrize("seq", [8, 16])
def test_chunked_equals_recurrence(chunk, seq):
    key = jax.random.PRNGKey(chunk * seq)
    bsz, h, p, n = 2, 3, 4, 5
    x = jax.random.normal(key, (bsz, seq, h, p))
    a = -jnp.abs(jax.random.normal(jax.random.fold_in(key, 1), (bsz, seq, h))) * 0.5
    b_mat = jax.random.normal(jax.random.fold_in(key, 2), (bsz, seq, n))
    c_mat = jax.random.normal(jax.random.fold_in(key, 3), (bsz, seq, n))
    y_chunk, s_chunk = _ssd_chunked(x, a, b_mat, c_mat, chunk)
    y_naive, s_naive = naive_ssd(x, a, b_mat, c_mat)
    np.testing.assert_allclose(y_chunk, y_naive, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(s_chunk, s_naive, rtol=1e-4, atol=1e-5)


def test_chunked_with_initial_state():
    key = jax.random.PRNGKey(9)
    bsz, seq, h, p, n = 1, 8, 2, 3, 4
    x = jax.random.normal(key, (bsz, seq, h, p))
    a = -jnp.abs(jax.random.normal(jax.random.fold_in(key, 1), (bsz, seq, h)))
    b_mat = jax.random.normal(jax.random.fold_in(key, 2), (bsz, seq, n))
    c_mat = jax.random.normal(jax.random.fold_in(key, 3), (bsz, seq, n))
    s0 = jax.random.normal(jax.random.fold_in(key, 4), (bsz, h, p, n))
    y_chunk, sf = _ssd_chunked(x, a, b_mat, c_mat, 4, init_state=s0)
    y_naive, sn = naive_ssd(x, a, b_mat, c_mat, init_state=s0)
    np.testing.assert_allclose(y_chunk, y_naive, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(sf, sn, rtol=1e-4, atol=1e-5)


def test_segsum_structure():
    a = jnp.array([1.0, 2.0, 3.0, 4.0])
    s = _segsum(a)
    assert s[2, 0] == pytest.approx(2.0 + 3.0)  # sum over (0, 2]
    assert s[3, 1] == pytest.approx(3.0 + 4.0)
    assert bool(jnp.all(jnp.isneginf(s[0, 1:])))  # strict upper = -inf
    assert s[1, 1] == 0.0  # diagonal: empty sum


def test_decay_stability_long_chunk():
    """Strong decay over a long chunk must not produce inf/nan (the segsum
    -inf trick must underflow to exactly 0 probability mass)."""
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (1, 64, 2, 4))
    a = jnp.full((1, 64, 2), -5.0)  # aggressive decay
    b_mat = jax.random.normal(jax.random.fold_in(key, 1), (1, 64, 8))
    c_mat = jax.random.normal(jax.random.fold_in(key, 2), (1, 64, 8))
    y, s = _ssd_chunked(x, a, b_mat, c_mat, 16)
    assert bool(jnp.all(jnp.isfinite(y))) and bool(jnp.all(jnp.isfinite(s)))
