"""Production step functions lowered by the dry-run and drivers.

  train_step   — next-token LM loss (chunked CE: (B,S,V) logits never
                 materialise), full-param AdamW, optional gradient-
                 accumulation microbatches (cfg.microbatches).
  prefill_step — full forward, last-position logits (B, V).
  serve_step   — one decode token against the KV/SSM cache.

All are pure (params/opt/batch in, params/opt/metrics out) and
pjit-compatible; shardings are attached by the caller (dryrun/train).

The serving-side factories (prefill/serve) live in :mod:`repro.serve.steps`
since the PR-10 serve redesign; ``make_serve_step``/``make_prefill_step``
stay importable here as shims (``make_serve_step`` == ``make_decode_step``).
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import backbone
from repro.models.model import _lm_logits  # internal head reuse (framework-private)
from repro.optim import AdamWState, adamw_update

# Serving steps moved to repro.serve (PR 10 api_redesign) — re-exported here
# so pre-redesign imports keep working, mirroring the PR-9 fed/engines shims.
from repro.serve.steps import (  # noqa: F401
    make_decode_step as make_serve_step,
    make_prefill_step,
)

__all__ = ["chunked_lm_loss", "make_train_step", "make_prefill_step", "make_serve_step"]

CE_CHUNK = 512  # sequence positions per cross-entropy chunk

# REPRO_UNROLL=1: python-unroll the CE chunk scan (HLO cost-mode; the while
# loop body is otherwise counted once by XLA cost analysis).
import os as _os  # noqa: E402

_UNROLL = _os.environ.get("REPRO_UNROLL", "0") == "1"


def chunked_lm_loss(
    params: dict, cfg: ModelConfig, h: jax.Array, targets: jax.Array, mask: jax.Array
) -> jax.Array:
    """Next-token CE summed over (B, S) in chunks over S.

    h (B,S,D) hidden states; targets/mask (B,S).  Each chunk computes
    its own head matmul + log-softmax, so peak memory is
    (B, CE_CHUNK, V/model_shards) instead of (B, S, V/model_shards).
    """
    b, s, d = h.shape
    chunk = min(CE_CHUNK, s)
    # pad S to a multiple of chunk (mask padding out)
    pad = (-s) % chunk
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    nc = h.shape[1] // chunk
    h_c = h.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)
    t_c = targets.reshape(b, nc, chunk).transpose(1, 0, 2)
    m_c = mask.reshape(b, nc, chunk).transpose(1, 0, 2)

    vocab_iota = jnp.arange(cfg.vocab_size, dtype=targets.dtype)

    def one(carry, xs):
        hc, tc, mc = xs
        logits = _lm_logits(params, cfg, hc).astype(jnp.float32)  # (B, chunk, V)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        # target logit via masked reduction, NOT take_along_axis: the gather
        # lowers to full-logits all-gathers under SPMD (§Perf iteration 3),
        # while this form partitions cleanly over the vocab shards.
        tgt_logit = jnp.sum(
            jnp.where(vocab_iota[None, None, :] == tc[..., None], logits, 0.0), axis=-1
        )
        nll = (logz - tgt_logit) * mc
        return carry + jnp.sum(nll), None

    if _UNROLL:
        total = jnp.zeros((), jnp.float32)
        for i in range(nc):
            total, _ = one(total, (h_c[i], t_c[i], m_c[i]))
    else:
        total, _ = jax.lax.scan(one, jnp.zeros((), jnp.float32), (h_c, t_c, m_c))
    return total / jnp.maximum(1.0, jnp.sum(mask))


def make_train_step(
    cfg: ModelConfig,
    *,
    lr: float = 3e-4,
    weight_decay: float = 0.1,
    router_aux_weight: float = 0.01,
) -> Callable:
    """LM pre-training/fine-tuning step over a {"tokens": (B, S)} batch
    (+optional "frontend").  Full-parameter AdamW."""

    def loss_fn(params, batch):
        h, aux = backbone(params, cfg, batch)
        tokens = batch["tokens"]
        targets = tokens[:, 1:]
        mask = jnp.ones_like(targets, jnp.float32)
        loss = chunked_lm_loss(params, cfg, h[:, :-1], targets, mask)
        return loss + router_aux_weight * aux.moe_aux, loss

    def grads_of(params, batch):
        (loss, ce), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        return loss, ce, grads

    def train_step(params, opt: AdamWState, batch):
        bsz = batch["tokens"].shape[0]
        m = cfg.microbatches
        if m > bsz or bsz % m != 0:
            m = 1  # smoke-scale batches: accumulate-free step
        if m <= 1:
            loss, ce, grads = grads_of(params, batch)
        else:
            # gradient accumulation: scan over microbatches, accumulate in
            # the param dtype (bf16 for the HBM-limited giants, DESIGN §4)
            def split(x):
                bsz = x.shape[0]
                return x.reshape((m, bsz // m) + x.shape[1:])

            micro = jax.tree.map(split, batch)

            def acc_fn(carry, mb):
                acc, loss_sum = carry
                loss, _, grads = grads_of(params, mb)
                acc = jax.tree.map(lambda a, g: a + g.astype(a.dtype), acc, grads)
                return (acc, loss_sum + loss), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype), params)
            (gsum, loss_sum), _ = jax.lax.scan(acc_fn, (zeros, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / m, gsum)
            loss = ce = loss_sum / m

        new_params, new_opt = adamw_update(
            grads, opt, params, lr=lr, weight_decay=weight_decay
        )
        return new_params, new_opt, {"loss": loss, "ce": ce}

    return train_step


