"""Checkpointing: pytree <-> .npz with path-flattened keys.

Good enough for single-host CPU runs and tests; on a real pod this module
would be swapped for a tensorstore-backed async writer, but the API
(save/restore/latest) is the deployment-shaped one.

Crash-safety contract (PR 8): :func:`save` is ATOMIC — the arrays and the
metadata sidecar are written to temp files in the target directory and
``os.replace``d into place, so a process killed mid-save can never leave a
truncated "latest" checkpoint under the final name.  :func:`latest_step`
additionally verifies candidates are readable zip archives and skips
partially-written/unparseable entries (e.g. leftovers from a pre-atomic
writer or a torn copy), so resume always lands on a loadable step.
"""

from __future__ import annotations

import json
import os
import re
import zipfile
from typing import Any

import jax
import numpy as np

__all__ = [
    "save",
    "restore",
    "restore_subtree",
    "latest_step",
    "save_step",
    "restore_step",
    "step_metadata",
    "fleet_shard_name",
    "list_fleet_shards",
    "fleet_shard_dir",
]

_SEP = "__"


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"idx{p.idx}"
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save(path: str, tree: Any, *, metadata: dict | None = None) -> None:
    """Atomically write ``tree`` (and optional JSON ``metadata`` sidecar).

    Both files are staged as temporaries in the destination directory and
    moved into place with ``os.replace`` (atomic within a filesystem), the
    arrays FIRST: a crash between the two replaces leaves a valid array
    file with a stale/absent sidecar, never a torn one.
    """
    if not path.endswith(".npz"):
        path = path + ".npz"
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    tmp = f"{path}.tmp.{os.getpid()}"
    try:
        with open(tmp, "wb") as f:
            np.savez(f, **flat)  # a file OBJECT: savez cannot rename it
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)
    if metadata is not None:
        meta_path = path + ".meta.json"
        tmp = f"{meta_path}.tmp.{os.getpid()}"
        try:
            with open(tmp, "w") as f:
                json.dump(metadata, f)
            os.replace(tmp, meta_path)
        finally:
            if os.path.exists(tmp):
                os.remove(tmp)


def restore(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (shapes/dtypes preserved).

    Raises ``ValueError`` (not a bare ``assert``, which vanishes under
    ``python -O``) naming the offending key when the checkpoint is missing
    a leaf or stores one at a different shape than ``like`` expects.
    """
    if not path.endswith(".npz"):
        path = path + ".npz"
    data = np.load(path)
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path_keys, leaf in paths:
        key = _SEP.join(_path_str(p) for p in path_keys)
        if key not in data:
            raise ValueError(
                f"checkpoint {path} has no entry for {key!r} — the stored "
                "tree does not match the requested structure"
            )
        arr = data[key]
        if arr.shape != tuple(leaf.shape):
            raise ValueError(
                f"checkpoint {path} entry {key!r} has shape {arr.shape}, "
                f"but the target structure expects {tuple(leaf.shape)}"
            )
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def restore_subtree(path: str, prefix: str) -> Any:
    """Load the subtree stored under ``prefix`` WITHOUT a skeleton.

    :func:`restore` needs a ``like`` structure; serving-side consumers
    (``repro.serve.export_adapters``) read a checkpoint they did not write
    and reconstruct the nested-dict tree from the path-flattened keys
    instead.  ``prefix`` is a flattened key prefix (e.g. ``"fleet__lora"``
    or just ``"lora"``); the returned tree is nested host-numpy dicts.
    Only dict-keyed trees round-trip this way — which is all the repo's
    param/fleet trees are.  Raises ``KeyError`` when nothing matches.
    """
    if not path.endswith(".npz"):
        path = path + ".npz"
    data = np.load(path)
    out: dict = {}
    hit = False
    lead = prefix + _SEP
    for key in data.files:
        if key == prefix:
            return np.asarray(data[key])  # the prefix IS a leaf
        if not key.startswith(lead):
            continue
        hit = True
        node = out
        parts = key[len(lead):].split(_SEP)
        for part in parts[:-1]:
            node = node.setdefault(part, {})
        node[parts[-1]] = np.asarray(data[key])
    if not hit:
        raise KeyError(
            f"checkpoint {path} holds no keys under prefix {prefix!r}"
        )
    return out


def _step_path(ckpt_dir: str, step: int) -> str:
    return os.path.join(ckpt_dir, f"step_{step:08d}.npz")


def save_step(ckpt_dir: str, step: int, tree: Any, **meta) -> str:
    path = _step_path(ckpt_dir, step)
    save(path, tree, metadata={"step": step, **meta})
    return path


def latest_step(ckpt_dir: str) -> int | None:
    """Newest VALID step in ``ckpt_dir`` (None when there is none).

    A candidate must both match the ``step_NNNNNNNN.npz`` name and be a
    readable zip archive — a truncated or corrupt file (crash mid-copy,
    disk-full tail) is skipped so resume falls back to the newest loadable
    step instead of dying on ``np.load``.
    """
    if not os.path.isdir(ckpt_dir):
        return None
    steps = sorted(
        (
            int(m.group(1))
            for f in os.listdir(ckpt_dir)
            if (m := re.match(r"step_(\d+)\.npz$", f))
        ),
        reverse=True,
    )
    for step in steps:
        path = _step_path(ckpt_dir, step)
        try:
            if zipfile.is_zipfile(path):
                return step
        except OSError:
            continue
    return None


def step_metadata(ckpt_dir: str, step: int) -> dict | None:
    """The JSON metadata sidecar saved with ``save_step`` (None when absent
    or unparseable — metadata is advisory, a torn sidecar must not block a
    restore of the arrays)."""
    path = _step_path(ckpt_dir, step) + ".meta.json"
    if not os.path.exists(path):
        return None
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError):
        return None


def restore_step(ckpt_dir: str, like: Any, step: int | None = None) -> tuple[Any, int]:
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    return restore(_step_path(ckpt_dir, step), like), step


# -- per-client fleet shards (PR 9) -------------------------------------
#
# A fleet-scale checkpoint splits the per-client state into range shards
# (``{prefix}_{lo:08d}_{hi:08d}.npz``, each written atomically via
# :func:`save`) plus the small main step npz.  The orchestration writes
# the shards FIRST and the main step file LAST, so the presence of a
# valid ``step_NNNNNNNN.npz`` implies its shards are complete — a crash
# mid-shard-write leaves no main file and :func:`latest_step` falls back
# to the previous step.  Shard directories (``step_NNNNNNNN.fleet/``) do
# not match the step-file pattern, so :func:`latest_step` ignores them.

_SHARD_RE = re.compile(r"^(?P<prefix>.+)_(?P<lo>\d{8})_(?P<hi>\d{8})\.npz$")


def fleet_shard_name(prefix: str, lo: int, hi: int) -> str:
    """Canonical file name of the shard holding clients ``[lo, hi)``."""
    return f"{prefix}_{lo:08d}_{hi:08d}.npz"


def fleet_shard_dir(ckpt_dir: str, step: int) -> str:
    """The shard directory riding alongside one step's main npz."""
    return os.path.join(ckpt_dir, f"step_{step:08d}.fleet")


def list_fleet_shards(dir_path: str, prefix: str = "fleet") -> list[tuple[int, int, str]]:
    """All ``(lo, hi, path)`` shard ranges of ``prefix`` in ``dir_path``,
    sorted by range.  Raises ``FileNotFoundError`` when the directory is
    missing (a sharded checkpoint whose shard dir vanished is corrupt)."""
    if not os.path.isdir(dir_path):
        raise FileNotFoundError(f"no fleet shard directory at {dir_path}")
    out = []
    for f in os.listdir(dir_path):
        m = _SHARD_RE.match(f)
        if m and m.group("prefix") == prefix:
            out.append(
                (int(m.group("lo")), int(m.group("hi")),
                 os.path.join(dir_path, f))
            )
    return sorted(out)
