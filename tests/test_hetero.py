"""Heterogeneous federated distillation (the paper's FedD motivation):
clients with DIFFERENT architectures interoperate through the logit/
projection exchange — only vocab and LoRA rank are shared contracts.

Fast tier: the family-bucketed FAST engines (PR 5) — a mixed dense + SSM
fleet runs through ``batched``/``fused``/``fused_e2e`` and the multi-round
scan at parity with the sequential reference (identical per-client adaptive
k and ledger bytes, 1e-6 accuracies), the union sparse wire matches the
dense uplink, and every transmitted payload still fits its Shannon budget.
Slow tier: the original three-family sequential round (kept as the
engine-free reference scenario).
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import LoRAConfig, SSMConfig
from repro.configs.gpt2_paper import REDUCED_CLIENT, REDUCED_SERVER
from repro.core import ChannelConfig, ChannelSimulator
from repro.core.topk import wire_densify
from repro.data import make_banking77_like, make_fed_benchmark_dataset, split_public_private
from repro.fed import FedConfig, run_federated
from repro.fed.client import Client
from repro.fed.cohort import partition_fleet, split_cohort, validate_family_contracts
from repro.fed.engine import HeteroFusedE2EEngine, SequentialEngine
from repro.fed.server import Server

# ---------------------------------------------------------------------------
# fast tier: family-bucketed fast engines at reduced scale
# ---------------------------------------------------------------------------

FLORA = LoRAConfig(rank=4, alpha=32.0, dropout=0.0, targets=("q", "v", "head"))
H_DENSE = REDUCED_CLIENT.with_overrides(
    name="h-dense", num_layers=2, d_model=64, num_heads=2, num_kv_heads=2,
    d_ff=128, vocab_size=256, max_seq_len=32, lora=FLORA,
)
H_SSM = get_smoke_config("mamba2-130m").with_overrides(
    name="h-ssm", d_model=64, vocab_size=256, max_seq_len=32, lora=FLORA,
    ssm=SSMConfig(state_dim=16, head_dim=16, expand=2, chunk_size=4),
)
H_SERVER = REDUCED_SERVER.with_overrides(
    num_layers=2, d_model=96, num_heads=2, num_kv_heads=2, d_ff=192,
    vocab_size=256, max_seq_len=32, lora=FLORA,
)
FAMILIES = [H_DENSE, H_SSM]
# Constrained uplink so the adaptive k actually varies per client/round.
H_CHAN = ChannelConfig(bandwidth_hz=2e5, mean_snr_db=2.0)


def _dataset():
    return make_banking77_like(vocab_size=256, seq_len=12, total=500, seed=0)


def _cfg(engine, channel=H_CHAN, rounds=2, **kw):
    kw.setdefault("pretrain_steps", 0)
    return FedConfig(
        method="adald", engine=engine, num_clients=4, clients_per_round=2,
        rounds=rounds, public_size=64, public_batch=16, eval_size=64,
        local_steps=2, distill_steps=1, server_distill_steps=2,
        seed=0, channel=channel, **kw,
    )


def _mixed_cohort(n=4, ds=None):
    """n clients cycling dense/SSM families (per-client random backbones —
    each bucket carries frozen_ax=0 stacked frozens)."""
    ds = ds or _dataset()
    return ds, [
        Client(i, FAMILIES[i % 2], ds.subset(np.arange(i * 60, (i + 1) * 60)),
               num_classes=ds.num_classes, seed=i, local_steps=1,
               distill_steps=1)
        for i in range(n)
    ]


def test_partition_fleet_buckets_by_config():
    ds, clients = _mixed_cohort(5)
    buckets = partition_fleet(clients)
    assert [b.cfg.name for b in buckets] == ["h-dense", "h-ssm"]
    assert buckets[0].client_ids == (0, 2, 4)
    assert buckets[1].client_ids == (1, 3)
    # per-client random backbones: nothing is identity-shared
    assert not any(b.shared_backbone for b in buckets)
    validate_family_contracts(buckets, server_cfg=H_SERVER)
    parts = split_cohort(buckets, [3, 0, 4])
    assert [(b.index, pos, local) for b, pos, local in parts] == [
        (0, [1, 2], [0, 2]), (1, [0], [1]),
    ]


def test_family_contracts_fail_fast():
    ds, clients = _mixed_cohort(2)
    odd_vocab = [
        clients[0],
        Client(1, H_SSM.with_overrides(vocab_size=512),
               ds.subset(np.arange(60, 120)), num_classes=ds.num_classes,
               seed=1, local_steps=1, distill_steps=1),
    ]
    with pytest.raises(ValueError, match="vocab"):
        validate_family_contracts(partition_fleet(odd_vocab))
    odd_rank = [
        clients[0],
        Client(1, H_SSM.with_overrides(
            lora=LoRAConfig(rank=8, targets=("q", "v", "head"))),
            ds.subset(np.arange(60, 120)), num_classes=ds.num_classes,
            seed=1, local_steps=1, distill_steps=1),
    ]
    with pytest.raises(ValueError, match="rank"):
        validate_family_contracts(partition_fleet(odd_rank))


@pytest.mark.parametrize("engine", ["batched", "fused", "fused_e2e"])
def test_hetero_engine_parity_with_sequential(engine):
    """The family-bucketed fast engines reproduce the sequential reference
    on a mixed dense+SSM fleet: identical per-client adaptive k and ledger
    bytes, accuracies at 1e-6."""
    ds = _dataset()
    seq = run_federated(FAMILIES, H_SERVER, ds, _cfg("sequential"))
    oth = run_federated(FAMILIES, H_SERVER, ds, _cfg(engine))
    assert seq.per_client_k == oth.per_client_k
    for rs, ro in zip(seq.ledger.rounds, oth.ledger.rounds):
        assert rs.uplink_bytes == ro.uplink_bytes
        assert rs.downlink_bytes == ro.downlink_bytes
        assert rs.num_transmitters == ro.num_transmitters
    np.testing.assert_allclose(seq.server_acc, oth.server_acc, atol=1e-6)
    np.testing.assert_allclose(seq.client_acc, oth.client_acc, atol=1e-6)


def test_hetero_straggler_dropout_parity():
    """Mixed fleet + outage stragglers: the bucketed engines agree with the
    sequential reference on who dropped and on everything else."""
    chan = ChannelConfig(bandwidth_hz=2e5, mean_snr_db=2.0, min_k=0,
                         dropout_prob=0.5)
    ds = _dataset()
    seq = run_federated(FAMILIES, H_SERVER, ds, _cfg("sequential", chan, rounds=3))
    e2e = run_federated(FAMILIES, H_SERVER, ds, _cfg("fused_e2e", chan, rounds=3))
    all_ks = [k for ks in seq.per_client_k for k in ks]
    assert 0 in all_ks and any(k > 0 for k in all_ks)
    assert seq.per_client_k == e2e.per_client_k
    np.testing.assert_allclose(seq.server_acc, e2e.server_acc, atol=1e-6)
    np.testing.assert_allclose(seq.client_acc, e2e.client_acc, atol=1e-6)


def test_hetero_scan_rounds_matches_loop():
    """run_rounds on a heterogeneous fleet — R whole rounds, per-bucket
    executables inside ONE lax.scan dispatch — matches the per-round path
    (identical k/bytes, 1e-6 accuracies) and reports one eval-tap accuracy
    per family."""
    ds = _dataset()
    loop = run_federated(FAMILIES, H_SERVER, ds, _cfg("fused_e2e", rounds=3))
    scan = run_federated(
        FAMILIES, H_SERVER, ds, _cfg("fused_e2e", rounds=3, scan_rounds=True)
    )
    assert loop.per_client_k == scan.per_client_k
    for a, b in zip(loop.ledger.rounds, scan.ledger.rounds):
        assert a.uplink_bytes == b.uplink_bytes
        assert a.downlink_bytes == b.downlink_bytes
        assert a.num_transmitters == b.num_transmitters
    np.testing.assert_allclose(loop.server_acc, scan.server_acc, atol=1e-6)
    np.testing.assert_allclose(loop.client_acc, scan.client_acc, atol=1e-6)
    np.testing.assert_allclose(loop.distill_loss, scan.distill_loss, rtol=1e-4)
    # the per-family tap: one accuracy per bucket per round, and the
    # cohort-first client's family entry IS the reported client_acc
    assert scan.family_client_acc is not None
    assert len(scan.family_client_acc) == 3
    assert all(len(row) == len(FAMILIES) for row in scan.family_client_acc)
    for r, row in enumerate(scan.family_client_acc):
        assert scan.client_acc[r] in row


def test_hetero_union_wire_matches_dense_and_fits_budget():
    """Engine-level: the union sparse wire of a mixed cohort densifies to the
    sequential engine's per-client dense uploads, a k == 0 straggler is
    absent, per-row transmitted-entry counts equal the adaptive budgets, and
    every transmitted payload — LoRA projection included — satisfies
    PayloadSpec.fits for the channel state it was computed from."""
    ds, c_seq = _mixed_cohort(4)
    _, c_het = _mixed_cohort(4)
    server = Server(H_SERVER, aggregation="adaptive", distill_steps=2)
    seq = SequentialEngine(c_seq, H_DENSE, k_min=0)
    het = HeteroFusedE2EEngine(
        c_het, server=server, num_classes=ds.num_classes, local_steps=1,
        distill_steps=1, server_distill_steps=2, k_min=0,
    )
    sim = ChannelSimulator(
        4, ChannelConfig(bandwidth_hz=2e5, mean_snr_db=0.0, min_k=0), seed=1
    )
    pub = jnp.asarray(ds.tokens[:16])
    sel = [0, 1, 2, 3]
    for rnd in range(3):
        states = sim.states_batched(rnd, sel)
        ps = seq.run_round(sel, pub, None, states, adaptive_k=True, send_h=True)
        pe = het.run_round(sel, pub, None, states, adaptive_k=True, send_h=True)
        assert ps.ks == pe.ks
        assert [p.bytes for p in ps.payloads] == [p.bytes for p in pe.payloads]
        for payload in pe.payloads:
            st = states[payload.client_id]
            assert payload.spec.fits(st), (rnd, payload.client_id, payload.spec)
        tx = [i for i, k in enumerate(pe.ks) if k > 0]
        if not tx:
            assert pe.sparse is None
            continue
        wire = pe.sparse
        assert wire.values.shape[0] == len(tx)
        counts = np.asarray(jnp.sum(wire.mask, axis=-1))
        for row, i in enumerate(tx):
            assert set(np.unique(counts[row])) == {pe.ks[i]}
        if ps.dense is not None:
            np.testing.assert_allclose(
                np.asarray(wire_densify(wire)), np.asarray(ps.dense), atol=1e-5
            )


# ---------------------------------------------------------------------------
# slow tier: the original engine-free three-family sequential scenario
# ---------------------------------------------------------------------------

VOCAB = 512
LORA = LoRAConfig(rank=8, targets=("q", "v", "head"))


@pytest.fixture(scope="module")
def hetero_round():
    dense = get_smoke_config("yi-9b").with_overrides(
        name="h-dense", vocab_size=VOCAB, lora=LORA, max_seq_len=64)
    ssm = get_smoke_config("mamba2-130m").with_overrides(
        name="h-ssm", vocab_size=VOCAB, lora=LORA, max_seq_len=64)
    moe = get_smoke_config("granite-moe-1b-a400m").with_overrides(
        name="h-moe", vocab_size=VOCAB, lora=LORA, max_seq_len=64)
    ds = make_fed_benchmark_dataset(VOCAB, seed=0, total=600)
    public, private = split_public_private(ds, 96, seed=0)
    clients = [
        Client(i, cfg, private.subset(np.arange(i * 100, (i + 1) * 100)),
               num_classes=77, seed=i, local_steps=1, distill_steps=1)
        for i, cfg in enumerate([dense, ssm, moe])
    ]
    server = Server(REDUCED_SERVER.with_overrides(vocab_size=VOCAB, num_layers=2,
                                                  d_model=128, num_heads=4,
                                                  num_kv_heads=4, d_ff=256,
                                                  lora=LORA),
                    distill_steps=1)
    chan = ChannelSimulator(3, ChannelConfig(), seed=0)
    pub = jnp.asarray(public.tokens[:32])
    ups = []
    for c, st in zip(clients, chan.states(0, [0, 1, 2])):
        c.local_train()
        ups.append(c.upload(pub, st))
    k_g, h_g = server.aggregate_uploads(ups)
    metrics = server.distill(pub, k_g, h_g)
    g_logits, g_h, bits = server.broadcast(pub)
    for c in clients:
        c.local_distill(pub, g_logits, g_h)
    return ups, k_g, h_g, metrics


@pytest.mark.slow
def test_mixed_families_interoperate(hetero_round):
    ups, k_g, h_g, metrics = hetero_round
    assert k_g.shape == (32, VOCAB)
    assert bool(jnp.all(jnp.isfinite(k_g)))
    assert np.isfinite(metrics["loss"])


@pytest.mark.slow
def test_projections_align_across_families(hetero_round):
    """h = A·x has the same (batch, rank) shape for every architecture —
    the cross-family exchange contract of paper eq. 8."""
    ups, _, h_g, _ = hetero_round
    for up in ups:
        assert up.h is not None and up.h.shape == (32, LORA.rank)
    assert h_g.shape == (32, LORA.rank)


@pytest.mark.slow
def test_channel_budgets_differ_per_client(hetero_round):
    ups, _, _, _ = hetero_round
    ks = [u.k for u in ups]
    assert all(1 <= k <= VOCAB for k in ks)
    # Under the fixture's default channel every budget caps at k = vocab
    # (since the PR-4 per-(seed, round, cid) RNG re-keying), so the
    # different-fades-/-different-k property is asserted on a CONSTRAINED
    # uplink where the Shannon budget actually binds.
    chan = ChannelSimulator(
        3, ChannelConfig(bandwidth_hz=2e5, mean_snr_db=2.0), seed=0
    )
    tight = chan.topk_for(0, [0, 1, 2], vocab_size=VOCAB, num_samples=32)
    assert len(set(tight)) > 1  # different fades -> different adaptive k


# ---- PR 7: correlated-channel scenarios -----------------------------------


def test_hetero_parity_correlated_scenario():
    """The family-bucketed engines reproduce the sequential reference on a
    mixed dense+SSM fleet under a gauss_markov correlated channel with
    outage-driven k=0 stragglers; the hetero multi-round scan carries the
    channel state too and exposes the in-scan tap."""
    ds = _dataset()
    chan = ChannelConfig(
        bandwidth_hz=2e5, mean_snr_db=2.0, min_k=0, dropout_prob=0.25
    )
    kw = dict(channel=chan, rounds=3, scenario="gauss_markov")
    seq = run_federated(FAMILIES, H_SERVER, ds, _cfg("sequential", **kw))
    assert any(k == 0 for ks in seq.per_client_k for k in ks)
    for engine in ("batched", "fused_e2e"):
        oth = run_federated(FAMILIES, H_SERVER, ds, _cfg(engine, **kw))
        assert oth.per_client_k == seq.per_client_k, engine
        for a, b in zip(seq.ledger.rounds, oth.ledger.rounds):
            assert a.uplink_bytes == b.uplink_bytes, engine
            assert a.num_transmitters == b.num_transmitters, engine
        np.testing.assert_allclose(oth.server_acc, seq.server_acc, atol=1e-6)
        np.testing.assert_allclose(oth.client_acc, seq.client_acc, atol=1e-6)
    scan = run_federated(
        FAMILIES, H_SERVER, ds, _cfg("fused_e2e", scan_rounds=True, **kw)
    )
    assert scan.per_client_k == seq.per_client_k
    for a, b in zip(seq.ledger.rounds, scan.ledger.rounds):
        assert a.uplink_bytes == b.uplink_bytes
    np.testing.assert_allclose(scan.server_acc, seq.server_acc, atol=1e-6)
    assert len(scan.outage) == 3
    for ks, out in zip(scan.per_client_k, scan.outage):
        for k, o in zip(ks, out):
            if o:
                assert k == 0
